"""Query planning for the interactive service: canonical cache keys + LRU
result/bounds caches, keyed off the logical-plan IR.

Two cache tiers, matching how a GUI session actually refines queries:

* **result cache** — keyed by the *whole* plan (predicate tree, ranking
  expression, k, order, mask_types, ROI content).  A repeated query is
  answered with zero mask loads.
* **bounds cache** — keyed **per value expression** by everything that
  determines the candidate set and the CHI bounds pass (expression, mask
  types, grouping, ROI content) but *not* by comparison op / threshold / k
  or by the rest of the plan.  A refined query (same expressions, new
  thresholds, rearranged boolean structure, or a larger LIMIT) reuses every
  prior bounds pass for free and pays only for the changed verification
  residue — and two *different* plans sharing a CP expression share its
  bounds entry.

Keys are canonical strings built from the frozen-dataclass expression reprs
(deterministic) plus a content hash of any caller-provided ROI array.

Both tiers fold the store's **epoch** into every key: the moment the mask
database mutates (append/update/delete), every pre-epoch result and bounds
entry becomes unreachable — a refined query after an ingest pays a fresh
bounds pass instead of pruning against a dead index.  The service also
sweeps the dead generation out eagerly (:meth:`Planner.evict_dead_epochs`)
so stale entries never squat in the LRU displacing live ones.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Optional

import numpy as np

from .. import lockcheck
from ..core.exprs import Node
from ..core.plan import LogicalPlan


def _as_plan(plan_or_query) -> LogicalPlan:
    if isinstance(plan_or_query, LogicalPlan):
        return plan_or_query
    return plan_or_query.plan          # queries.Query compat


def expr_signature(node: Optional[Node]) -> str:
    """Deterministic canonical form of an expression tree (frozen dataclass
    reprs are stable and include every field)."""
    return repr(node)


def roi_signature(rois: Optional[np.ndarray]) -> str:
    """Content hash of a provided-ROI array (the per-mask boxes a session
    queries against); two sessions sharing boxes share cache entries."""
    if rois is None:
        return "none"
    arr = np.ascontiguousarray(np.asarray(rois))
    return hashlib.sha1(arr.tobytes() + str(arr.shape).encode()).hexdigest()[:16]


def _backend_tag(backend: str, packed: bool) -> str:
    """Fold the store's mask representation into the backend key component
    (NOT a trailing component — ``evict_dead_epochs`` parses the epoch off
    the end).  A store re-ingested packed at the same epoch counter must
    never serve float-era cache entries, and vice versa."""
    return f"{backend}+packed" if packed else backend


def result_key(plan_or_query, roi_sig: str, backend: str = "host",
               epoch: int = 0, packed: bool = False) -> str:
    return "|".join([_as_plan(plan_or_query).signature(), roi_sig,
                     _backend_tag(backend, packed), f"e{int(epoch)}"])


def bounds_key(expr: Node, plan_or_query, roi_sig: str,
               backend: str = "host", epoch: int = 0,
               packed: bool = False, *, tier: int = 0) -> str:
    """One *value expression*'s bounds-cache key: everything that pins the
    candidate set + its CHI pass — NOT op/threshold/k or the rest of the
    plan, so refined and restructured queries hit the same entries.
    Keys carry the execution backend's name: bounds are numerically
    identical across backends, but entries stay attributable (and a
    service switching backends never serves stale placement decisions).
    They also carry the CHI pyramid **tier** the bounds were computed at
    (DESIGN.md §13) — a coarse-tier interval soundly *contains* the fine
    one, so serving it for a refined request would silently widen bounds;
    the tier component makes that impossible — and the store epoch, so a
    mutation makes every pre-epoch bounds pass unreachable, plus the
    packed-representation tag, so a float-era entry never answers for a
    packed store (or vice versa).  The epoch stays the trailing component
    (``evict_dead_epochs`` parses it off the end)."""
    plan = _as_plan(plan_or_query)
    return "|".join([
        expr_signature(expr),
        str(None if plan.mask_types is None
            else tuple(sorted(plan.mask_types))),
        str(plan.grouped), roi_sig, _backend_tag(backend, packed),
        f"t{int(tier)}", f"e{int(epoch)}",
    ])


@dataclasses.dataclass
class CacheInfo:
    hits: int = 0
    misses: int = 0
    evictions: int = 0           # displaced by the capacity bound
    invalidations: int = 0       # dropped because their epoch died
    size: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class LRUCache:
    """Tiny ordered-dict LRU with hit/miss/eviction accounting.

    Thread-safe: the service runs under ``ThreadingHTTPServer``, and a bare
    ``OrderedDict`` corrupts under concurrent ``get``/``put`` (move_to_end
    during iteration of a resize) — every operation holds a lock."""

    def __init__(self, capacity: int, name: str = "cache"):
        self.capacity = max(int(capacity), 0)
        self._data: OrderedDict = OrderedDict()
        self._lock = lockcheck.make_lock(f"planner.{name}")
        self.info = CacheInfo()

    def get(self, key):
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.info.hits += 1
                return self._data[key]
            self.info.misses += 1
            return None

    def put(self, key, value) -> None:
        with self._lock:
            if self.capacity == 0:
                return
            if key in self._data:
                self._data.move_to_end(key)
            self._data[key] = value
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.info.evictions += 1
            self.info.size = len(self._data)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def evict_where(self, pred) -> int:
        """Drop every entry whose key satisfies ``pred`` (accounted as
        invalidations, not capacity evictions).  Returns the count."""
        with self._lock:
            dead = [k for k in self._data if pred(k)]
            for k in dead:
                del self._data[k]
            self.info.invalidations += len(dead)
            self.info.size = len(self._data)
            return len(dead)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self.info.size = 0


class _PlanBoundsHook:
    """Adapts the planner's LRU to the engine's per-run bounds hook
    (``get(expr, tier)`` / ``put(expr, lb, ub, tier)``), closing over the
    plan context that pins the candidate set; the engine passes the tier
    the pass ran at (the finest grid on the classic path)."""

    def __init__(self, cache: LRUCache, plan: LogicalPlan, roi_sig: str,
                 backend: str = "host", epoch: int = 0,
                 packed: bool = False):
        self._cache = cache
        self._plan = plan
        self._roi_sig = roi_sig
        self._backend = backend
        self._epoch = epoch
        self._packed = packed

    def get(self, expr: Node, tier: int = 0):
        return self._cache.get(
            bounds_key(expr, self._plan, self._roi_sig, self._backend,
                       self._epoch, self._packed, tier=tier))

    def put(self, expr: Node, lb: np.ndarray, ub: np.ndarray,
            tier: int = 0) -> None:
        self._cache.put(
            bounds_key(expr, self._plan, self._roi_sig, self._backend,
                       self._epoch, self._packed, tier=tier),
            (lb, ub))


class Planner:
    """Canonicalizes plans into cache keys and owns the two caches."""

    def __init__(self, *, result_cache_size: int = 128,
                 bounds_cache_size: int = 64):
        self.result_cache = LRUCache(result_cache_size, name="results")
        self.bounds_cache = LRUCache(bounds_cache_size, name="bounds")

    # -- result tier ------------------------------------------------------
    def cached_result(self, plan_or_query, roi_sig: str,
                      backend: str = "host", epoch: int = 0,
                      packed: bool = False):
        return self.result_cache.get(
            result_key(plan_or_query, roi_sig, backend, epoch, packed))

    def store_result(self, plan_or_query, roi_sig: str, payload,
                     backend: str = "host", epoch: int = 0,
                     packed: bool = False) -> None:
        self.result_cache.put(
            result_key(plan_or_query, roi_sig, backend, epoch, packed),
            payload)

    # -- bounds tier ------------------------------------------------------
    def bounds_hook(self, plan_or_query, roi_sig: str,
                    backend: str = "host", epoch: int = 0,
                    packed: bool = False) -> _PlanBoundsHook:
        """The per-expression bounds cache, scoped to one plan's candidate
        set at one store epoch — hand this to
        :func:`repro.core.plan.compile_plan`."""
        return _PlanBoundsHook(self.bounds_cache, _as_plan(plan_or_query),
                               roi_sig, backend, epoch, packed)

    def evict_dead_epochs(self, epoch: int) -> int:
        """Drop every result/bounds entry keyed to an epoch other than
        ``epoch``.  Both key builders end with an ``e<epoch>`` component,
        so a mutation makes pre-epoch entries *unreachable* — but without
        this sweep they would still squat in the LRU, displacing live
        entries until enough new traffic ages them out.  Called by the
        service on every ingest/delete; returns the number dropped."""
        tag = f"e{int(epoch)}"

        def dead(key: str) -> bool:
            return key.rsplit("|", 1)[-1] != tag

        return (self.result_cache.evict_where(dead) +
                self.bounds_cache.evict_where(dead))

    def stats(self) -> dict:
        return {"result_cache": self.result_cache.info.as_dict(),
                "bounds_cache": self.bounds_cache.info.as_dict()}

    def register_metrics(self, registry) -> None:
        """Expose both cache tiers on a :class:`~repro.obs.metrics.
        MetricsRegistry` — pull-based, so every scrape reflects the live
        :class:`CacheInfo` without touching the query path."""
        from ..obs.metrics import dataclass_sampler
        registry.register_collector(dataclass_sampler(
            "masksearch_result_cache", "gauge",
            "Planner result-cache (whole-plan LRU) state",
            lambda: self.result_cache.info))
        registry.register_collector(dataclass_sampler(
            "masksearch_bounds_cache", "gauge",
            "Planner bounds-cache (per-expression LRU) state",
            lambda: self.bounds_cache.info))
