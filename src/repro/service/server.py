"""Thin stdlib HTTP/JSON front for :class:`MaskSearchService`.

Two route namespaces share one service:

* ``/v1/...`` — the versioned API (DESIGN.md §14): structured error
  envelopes ``{"error": {"code", "type", "message", "retry_after"?}}``,
  ``{"epoch", "applied", ...}`` mutation responses, and opaque
  continuation cursors for session paging (``POST /v1/page`` with
  ``{"cursor": ...}`` → ``{"cursor"|null, "items", "exhausted", ...}``).
  The route core lives in :mod:`.routes`, shared with the async tier
  (:mod:`.asyncserver`), so the two fronts cannot drift.
* unversioned legacy routes — thin compat shims over the same service
  methods, serving the historical payloads byte-identically.  Deprecated
  in favour of ``/v1`` (see README); they remain until a major rev.

Legacy endpoints (all JSON):

* ``POST /query``    — body ``{"sql": "...", "session": bool?,
  "page_size": int?, "rois": [[r0,c0,r1,c1], ...]?}`` → one result, or the
  first page + ``session`` id.  WHERE clauses compose with AND/OR/NOT and
  with ORDER BY … LIMIT (predicate-filtered rankings paginate too).
* ``POST /workload`` — body ``{"sqls": ["...", ...]}`` → list of results,
  verified in fused cross-query passes.
* ``POST /ingest``   — body ``{"masks": [[[...]]], "mask_ids": [...]?,
  "image_ids": [...]?, "model_ids": int|[...]?, "mask_types": int|[...]?,
  "on_conflict": "error"|"update"}`` → append/upsert masks; CHI rows are
  maintained incrementally and the store epoch advances.
* ``POST /delete``   — body ``{"mask_ids": [...]}`` → remove masks.
* ``GET /session/<id>/page?k=N`` — next page of an open session (409 if
  the session's pinned epoch can no longer be served after a mutation).
* ``DELETE /session/<id>``       — drop a session.
* ``GET /stats``     — cache / I/O / session counters + the store epoch,
  per-session phase breakdowns, and query-phase latency summaries.
* ``GET /metrics``   — the Prometheus text exposition (service registry +
  process-global kernel/jit/backend counters); not JSON.
* ``GET /trace/<query_id>`` — a retained span tree (``<query_id>`` =
  ``last`` → most recent; ``?format=chrome`` → Chrome trace-event JSON,
  loadable in Perfetto).  Traces are retained for every query when the
  server runs with ``--trace``, and always for ``EXPLAIN ANALYZE``.
* ``GET /healthz``   — liveness.

Run it::

    PYTHONPATH=src python -m repro.service.server --synthetic 500 --port 8765
    PYTHONPATH=src python -m repro.service.server --root /path/to/maskdb
"""

from __future__ import annotations

import argparse
import json
import re
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

import numpy as np

from . import routes
from .api import MaskSearchService
from .errors import NotFoundError, error_envelope

_SESSION_PAGE_RE = re.compile(r"^/session/([^/]+)/page$")
_SESSION_RE = re.compile(r"^/session/([^/]+)$")
_TRACE_RE = re.compile(r"^(?:/v1)?/trace/([^/]+)$")


class ServiceHandler(BaseHTTPRequestHandler):
    service: MaskSearchService = None  # bound by make_server
    verbose: bool = False

    # -- plumbing ---------------------------------------------------------
    def log_message(self, fmt, *args):  # noqa: N802
        if self.verbose:
            super().log_message(fmt, *args)

    def _send(self, obj, code: int = 200, *,
              retry_after: float | None = None) -> None:
        body = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        if retry_after is not None:
            self.send_header("Retry-After",
                             str(max(1, int(-(-retry_after // 1)))))
        self.end_headers()
        self.wfile.write(body)

    def _send_text(self, text: str, code: int = 200,
                   content_type: str = "text/plain; version=0.0.4; "
                                       "charset=utf-8") -> None:
        body = text.encode()
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _error(self, code: int, message: str) -> None:
        self._send({"error": message}, code)

    def _body(self) -> dict:
        length = int(self.headers.get("Content-Length") or 0)
        raw = self.rfile.read(length) if length else b"{}"
        return json.loads(raw or b"{}")

    def _guard(self, fn, *, v1: bool = False):
        """Run one handler, translating exceptions to HTTP errors.

        ``NotFoundError`` — not bare ``KeyError`` — is what maps to 404:
        a genuine ``KeyError`` escaping from engine internals is a server
        fault and surfaces as the 500 it is, instead of masquerading as
        "not found".  ``/v1`` routes serve the structured error envelope;
        legacy routes keep their historical ``{"error": "<str>"}`` body.
        """
        try:
            fn()
        except Exception as e:              # noqa: BLE001 — serving loop
            status, envelope, retry_after = error_envelope(e)
            if v1:
                self._send(envelope, status, retry_after=retry_after)
            else:
                self._error(status, envelope["error"]["message"])

    # -- /v1 routes (shaping shared with the async tier via .routes) ------
    def _post_v1(self, path: str) -> bool:
        if path == "/v1/query":
            def run():
                body = self._body()
                self._send(routes.shape_query(
                    self.service.query(**routes.query_kwargs(body))))
            self._guard(run, v1=True)
            return True
        if path == "/v1/workload":
            def run():
                body = self._body()
                self._send(routes.shape_workload(self.service.submit_batch(
                    routes.workload_sqls(body),
                    rois=routes.parse_rois(body))))
            self._guard(run, v1=True)
            return True
        if path == "/v1/page":
            def run():
                sid, k = routes.page_request(self._body())
                self._send(routes.shape_page(self.service.next_page(sid, k)))
            self._guard(run, v1=True)
            return True
        if path == "/v1/ingest":
            def run():
                self._send(routes.shape_ingest(self.service.ingest(
                    **routes.ingest_kwargs(self._body()))))
            self._guard(run, v1=True)
            return True
        if path == "/v1/delete":
            def run():
                self._send(routes.shape_delete(self.service.delete(
                    routes.delete_ids(self._body()))))
            self._guard(run, v1=True)
            return True
        if path == "/v1/session/drop":
            def run():
                body = self._body()
                if "cursor" not in body:
                    raise ValueError("body must contain 'cursor'")
                sid = routes.decode_cursor(body["cursor"])
                self._send({"dropped": self.service.drop_session(sid)})
            self._guard(run, v1=True)
            return True
        return False

    # -- routes -----------------------------------------------------------
    def do_POST(self):  # noqa: N802
        path = urlparse(self.path).path
        if path.startswith("/v1/"):
            if not self._post_v1(path):
                self._send(error_envelope(
                    NotFoundError(f"no route {path}"))[1], 404)
            return
        if path == "/query":
            def run():
                body = self._body()
                if "sql" not in body:
                    raise ValueError("body must contain 'sql'")
                rois = body.get("rois")
                self._send(self.service.query(
                    body["sql"],
                    rois=np.asarray(rois, np.int64) if rois else None,
                    session=bool(body.get("session", False)),
                    page_size=body.get("page_size")))
            return self._guard(run)
        if path == "/workload":
            def run():
                body = self._body()
                if "sqls" not in body:
                    raise ValueError("body must contain 'sqls'")
                rois = body.get("rois")
                self._send(self.service.submit_batch(
                    body["sqls"],
                    rois=np.asarray(rois, np.int64) if rois else None))
            return self._guard(run)
        if path == "/ingest":
            def run():
                body = self._body()
                if "masks" not in body:
                    raise ValueError("body must contain 'masks'")
                self._send(self.service.ingest(
                    np.asarray(body["masks"], np.float32),
                    mask_ids=body.get("mask_ids"),
                    image_ids=body.get("image_ids"),
                    model_ids=body.get("model_ids"),
                    mask_types=body.get("mask_types"),
                    on_conflict=body.get("on_conflict", "error")))
            return self._guard(run)
        if path == "/delete":
            def run():
                body = self._body()
                if "mask_ids" not in body:
                    raise ValueError("body must contain 'mask_ids'")
                self._send(self.service.delete(body["mask_ids"]))
            return self._guard(run)
        self._error(404, f"no route {path}")

    def do_GET(self):  # noqa: N802
        parsed = urlparse(self.path)
        v1 = parsed.path.startswith("/v1/")
        m = _SESSION_PAGE_RE.match(parsed.path)
        if m:
            sid = m.group(1)

            def run():
                qs = parse_qs(parsed.query)
                try:
                    k = int(qs["k"][0]) if "k" in qs else None
                except ValueError:
                    raise ValueError(f"bad page size k={qs['k'][0]!r}")
                self._send(self.service.next_page(sid, k))
            return self._guard(run)
        m = _TRACE_RE.match(parsed.path)
        if m:
            qid = m.group(1)

            def run():
                qs = parse_qs(parsed.query)
                fmt = (qs.get("format") or ["json"])[0]
                if fmt not in ("json", "chrome"):
                    raise ValueError(f"format must be json|chrome, "
                                     f"got {fmt!r}")
                self._send(self.service.trace(qid, fmt=fmt))
            return self._guard(run, v1=v1)
        if parsed.path in ("/stats", "/v1/stats"):
            return self._guard(lambda: self._send(self.service.stats()),
                               v1=v1)
        if parsed.path in ("/metrics", "/v1/metrics"):
            return self._guard(
                lambda: self._send_text(self.service.metrics_text()), v1=v1)
        if parsed.path in ("/healthz", "/v1/healthz"):
            return self._send({"ok": True})
        if v1:
            return self._send(error_envelope(
                NotFoundError(f"no route {parsed.path}"))[1], 404)
        self._error(404, f"no route {parsed.path}")

    def do_DELETE(self):  # noqa: N802
        m = _SESSION_RE.match(urlparse(self.path).path)
        if m:
            return self._guard(lambda: self._send(
                {"dropped": self.service.drop_session(m.group(1))}))
        self._error(404, "no route")


def make_server(service: MaskSearchService, host: str = "127.0.0.1",
                port: int = 0, *, verbose: bool = False) -> ThreadingHTTPServer:
    """Bind a threading HTTP server to the service (port 0 → ephemeral)."""
    handler = type("BoundServiceHandler", (ServiceHandler,),
                   {"service": service, "verbose": verbose})
    return ThreadingHTTPServer((host, port), handler)


def _synthetic_store(n: int, size: int):
    from ..core import CHIConfig, MaskStore
    from ..core.store import MASK_META_DTYPE
    from ..data.masks import object_boxes, saliency_masks
    rois = object_boxes(n, size, size, seed=1)
    masks, _ = saliency_masks(n, size, size, seed=0, attacked_fraction=0.15,
                              boxes=rois)
    meta = np.zeros(n, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n)
    meta["image_id"] = np.arange(n) // 2
    meta["mask_type"] = np.arange(n) % 2 + 1
    cfg = CHIConfig(grid=16, num_bins=16, height=size, width=size)
    return MaskStore.create_memory(masks, meta, cfg), rois


def main(argv=None):
    ap = argparse.ArgumentParser(description="MaskSearch query service")
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--root", help="existing on-disk mask DB root")
    src.add_argument("--synthetic", type=int, metavar="N",
                     help="serve an N-mask synthetic in-memory DB")
    ap.add_argument("--size", type=int, default=128,
                    help="mask side for --synthetic")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8765)
    ap.add_argument("--verify-batch", type=int, default=256)
    ap.add_argument("--backend", default="host",
                    choices=("host", "device", "mesh"),
                    help="physical execution layer (core/backend.py): host "
                         "NumPy, HBM-resident single device, or the "
                         "shard_map mesh over all local devices")
    ap.add_argument("--trace", action="store_true",
                    help="trace every query (span trees retrievable at "
                         "GET /trace/<query_id>); EXPLAIN ANALYZE traces "
                         "its query regardless")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    if args.root:
        from ..core import MaskStore
        store, rois = MaskStore.open_disk(args.root), None
    else:
        store, rois = _synthetic_store(args.synthetic, args.size)
    service = MaskSearchService(store, provided_rois=rois,
                                verify_batch=args.verify_batch,
                                backend=args.backend, trace=args.trace)
    httpd = make_server(service, args.host, args.port, verbose=args.verbose)
    host, port = httpd.server_address[:2]
    print(f"masksearch service: {len(store)} masks on http://{host}:{port}",
          flush=True)
    try:
        httpd.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        httpd.server_close()
        service.close()


if __name__ == "__main__":
    main()
