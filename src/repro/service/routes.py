"""Transport-agnostic ``/v1`` API core: request parsing, opaque
continuation cursors, and response shaping.

Both HTTP fronts — the legacy threaded server (:mod:`.server`) and the
async tier (:mod:`.asyncserver`) — route through this module, so the
``/v1`` contract cannot drift between them:

* **Uniform envelopes** — errors are structured
  ``{"error": {"code", "type", "message", "retry_after"?}}``
  (:mod:`.errors`); mutations return ``{"epoch", "applied", ...}``;
  session paging speaks opaque continuation cursors
  (``{"cursor": ...}`` in, ``{"cursor"|null, "items", "exhausted"}``
  out) instead of bare session ids.
* **Legacy shims** — the unversioned routes keep serving byte-identical
  payloads: they call the same service methods and return the raw
  (historical) payload untouched; ``/v1`` responses are a *reshaping* of
  that same payload, so the two can never disagree on content.

Cursor format (DESIGN.md §14): ``c1.<base64url(json {"s": sid, "o":
served})>`` — versioned, unpadded, order-stable.  The ``o`` component is
advisory (the session tracks its own frontier); decoding never trusts it
for anything but surfacing ``offset`` to the caller.  A bare legacy
session id is accepted where a cursor is expected, so mixed-era clients
interoperate.
"""

from __future__ import annotations

import base64
import binascii
import json
from typing import Optional

import numpy as np

from .errors import BadCursorError

_CURSOR_PREFIX = "c1."


# -- opaque continuation cursors ------------------------------------------

def encode_cursor(session_id: str, served: int) -> str:
    raw = json.dumps({"s": session_id, "o": int(served)},
                     separators=(",", ":")).encode()
    return _CURSOR_PREFIX + \
        base64.urlsafe_b64encode(raw).rstrip(b"=").decode()


def decode_cursor(cursor: str) -> str:
    """→ session id.  Accepts a bare legacy session id for interop."""
    if not isinstance(cursor, str) or not cursor:
        raise BadCursorError(f"cursor must be a non-empty string, "
                             f"got {cursor!r}")
    if not cursor.startswith(_CURSOR_PREFIX):
        return cursor                       # bare legacy session id
    payload = cursor[len(_CURSOR_PREFIX):]
    try:
        pad = "=" * (-len(payload) % 4)
        obj = json.loads(base64.urlsafe_b64decode(payload + pad))
        return obj["s"]
    except (ValueError, KeyError, TypeError, binascii.Error) as e:
        raise BadCursorError(f"undecodable cursor {cursor!r}") from e


# -- request parsing (shared by both fronts) ------------------------------

def parse_rois(body: dict) -> Optional[np.ndarray]:
    rois = body.get("rois")
    return np.asarray(rois, np.int64) if rois else None


def query_kwargs(body: dict) -> dict:
    """Body of POST /query | /v1/query → ``service.query`` kwargs."""
    if "sql" not in body:
        raise ValueError("body must contain 'sql'")
    return {"sql": body["sql"], "rois": parse_rois(body),
            "session": bool(body.get("session", False)),
            "page_size": body.get("page_size")}


def workload_sqls(body: dict) -> list:
    if "sqls" not in body:
        raise ValueError("body must contain 'sqls'")
    return list(body["sqls"])


def ingest_kwargs(body: dict) -> dict:
    if "masks" not in body:
        raise ValueError("body must contain 'masks'")
    return {"masks": np.asarray(body["masks"], np.float32),
            "mask_ids": body.get("mask_ids"),
            "image_ids": body.get("image_ids"),
            "model_ids": body.get("model_ids"),
            "mask_types": body.get("mask_types"),
            "on_conflict": body.get("on_conflict", "error")}


def delete_ids(body: dict) -> list:
    if "mask_ids" not in body:
        raise ValueError("body must contain 'mask_ids'")
    return body["mask_ids"]


def page_request(body: dict) -> tuple[str, Optional[int]]:
    """Body of POST /v1/page → (session id, k)."""
    if "cursor" not in body:
        raise ValueError("body must contain 'cursor'")
    k = body.get("k")
    if k is not None:
        try:
            k = int(k)
        except (TypeError, ValueError):
            raise ValueError(f"bad page size k={k!r}")
    return decode_cursor(body["cursor"]), k


# -- /v1 response shaping --------------------------------------------------
# Each shaper takes the *legacy* service payload (the raw dict the
# MaskSearchService method returned) and reshapes it; the legacy routes
# serve that input untouched, which is what keeps the shims byte-identical.

def shape_page(payload: dict) -> dict:
    """Legacy session/page payload → the /v1 cursor contract."""
    page = payload["page"]
    items = [{"id": i, "score": s}
             for i, s in zip(page["ids"], page["scores"])]
    exhausted = bool(payload["exhausted"])
    out = {
        "kind": payload["kind"],
        "items": items,
        "cursor": (None if exhausted
                   else encode_cursor(payload["session"], payload["served"])),
        "exhausted": exhausted,
        "offset": page["offset"],
        "served": payload["served"],
        "total_candidates": payload["total_candidates"],
        "stats": payload["stats"],
        "cache_hit": payload["cache_hit"],
    }
    if "query_id" in payload:
        out["query_id"] = payload["query_id"]
    return out


def shape_query(payload: dict) -> dict:
    """Legacy one-shot / session-open query payload → /v1 shape.

    One-shots already fit the contract (kind + ids/scores/value + stats);
    session opens become the cursor-paged shape."""
    if "page" in payload and "session" in payload:
        return shape_page(payload)
    if payload.get("explain"):
        return payload                       # EXPLAIN report: verbatim
    return payload


def shape_workload(payloads: list) -> dict:
    return {"items": [shape_query(p) for p in payloads]}


def shape_ingest(payload: dict) -> dict:
    return {"epoch": payload["epoch"],
            "applied": {"appended": payload["appended"],
                        "updated": payload["updated"]},
            "n_masks": payload["n_masks"],
            "mask_ids": payload["mask_ids"],
            "evicted_cache_entries": payload["evicted_cache_entries"]}


def shape_delete(payload: dict) -> dict:
    return {"epoch": payload["epoch"],
            "applied": {"deleted": payload["deleted"]},
            "n_masks": payload["n_masks"],
            "evicted_cache_entries": payload["evicted_cache_entries"]}
