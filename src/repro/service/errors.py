"""Service error taxonomy + the /v1 structured error envelope.

Every error a ``/v1`` route can return is one JSON shape::

    {"error": {"code": "<machine code>", "type": "<exception class>",
               "message": "<human text>", "retry_after": <seconds>?}}

``code`` is a small closed vocabulary (the API contract — see DESIGN.md
§14's error-code taxonomy); ``type`` is the Python exception class that
produced it (diagnostic, not contractual).  ``retry_after`` appears only
on shed responses (429) and mirrors the ``Retry-After`` HTTP header.

Legacy unversioned routes keep their historical ``{"error": "<str>"}``
bodies; only the mapping from exception to HTTP status is shared.
"""

from __future__ import annotations

from ..core.store import StaleRunError

__all__ = [
    "NotFoundError", "RateLimitedError", "OverloadedError",
    "BadCursorError", "error_status", "error_envelope",
]


class NotFoundError(KeyError):
    """An addressable resource (session, trace, route) does not exist.

    Subclasses ``KeyError`` so direct API callers that historically caught
    ``KeyError`` keep working — but the HTTP guards catch *this* class for
    404, so a genuine ``KeyError`` escaping from engine internals surfaces
    as the 500 it really is instead of masquerading as "not found".
    """

    def __str__(self) -> str:  # KeyError repr()s its message; undo that
        return self.args[0] if self.args else ""


class BadCursorError(ValueError):
    """An opaque continuation cursor failed to decode."""


class RateLimitedError(Exception):
    """A tenant exceeded its token-bucket quota; retry after a delay."""

    def __init__(self, message: str, retry_after: float):
        super().__init__(message)
        self.retry_after = float(retry_after)


class OverloadedError(Exception):
    """A bounded request queue (or the connection budget) is full — the
    tier sheds instead of queueing unboundedly; retry after a delay."""

    def __init__(self, message: str, retry_after: float):
        super().__init__(message)
        self.retry_after = float(retry_after)


# exception class -> (HTTP status, envelope code), most-specific first.
_TAXONOMY: tuple = (
    (RateLimitedError, (429, "rate_limited")),
    (OverloadedError, (429, "overloaded")),
    (NotFoundError, (404, "not_found")),
    (StaleRunError, (409, "stale_epoch")),
    (BadCursorError, (400, "bad_cursor")),
    (SyntaxError, (400, "bad_request")),
    (ValueError, (400, "bad_request")),
)


def error_status(exc: BaseException) -> tuple[int, str]:
    """→ (HTTP status, envelope code) for any exception (500/internal
    fallback).  A genuine ``KeyError`` is *not* in the taxonomy: it maps
    to 500 like any other engine fault."""
    for cls, mapping in _TAXONOMY:
        if isinstance(exc, cls):
            return mapping
    return 500, "internal"


def error_envelope(exc: BaseException) -> tuple[int, dict, float | None]:
    """→ (HTTP status, /v1 error body, retry_after seconds or None)."""
    status, code = error_status(exc)
    if status == 500:
        message = f"{type(exc).__name__}: {exc}"
    else:
        message = str(exc)
    err: dict = {"code": code, "type": type(exc).__name__,
                 "message": message}
    retry_after = getattr(exc, "retry_after", None)
    if retry_after is not None:
        err["retry_after"] = float(retry_after)
    return status, {"error": err}, retry_after
