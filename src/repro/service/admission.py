"""Admission control for the async serving tier (DESIGN.md §14).

Three mechanisms, composed by :class:`AdmissionController`:

* **Token-bucket quotas** — one bucket per tenant (``rate`` tokens/s,
  ``burst`` capacity).  A request with no token is shed *immediately*
  with :class:`~.errors.RateLimitedError` carrying the exact refill
  wait, which becomes ``Retry-After``.  Quotas bound each tenant's
  admission *rate*; they say nothing about ordering.
* **Bounded per-tenant queues** — admitted work waits in a FIFO per
  tenant, each bounded by ``depth``.  A full queue sheds with
  :class:`~.errors.OverloadedError` instead of queueing unboundedly:
  under overload the tier's memory and tail latency stay flat and the
  client is told when to come back (429 + ``Retry-After``), which is the
  load-shedding contract the ISSUE pins.
* **Weighted fair dequeue** — the dispatcher drains the queues by
  deficit round robin (DRR): each visit grants a tenant
  ``quantum x weight`` deficit and dequeues while the deficit covers a
  unit cost, so a tenant flooding its own queue cannot starve the
  others, and weights buy proportional throughput, not priority
  inversion.

Everything here runs on the event loop thread (single-threaded by
construction — no locks); only the counters are read cross-thread by the
``/metrics`` scraper, which tolerates torn reads of monotonic ints.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Optional

from .errors import OverloadedError, RateLimitedError

__all__ = ["TokenBucket", "FairQueue", "AdmissionController",
           "AdmissionStats"]


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s refill toward ``burst``."""

    __slots__ = ("rate", "burst", "tokens", "t_last")

    def __init__(self, rate: float, burst: float):
        self.rate = max(float(rate), 1e-9)
        self.burst = max(float(burst), 1.0)
        self.tokens = self.burst
        self.t_last: Optional[float] = None

    def try_take(self, now: float, n: float = 1.0) -> float:
        """Take ``n`` tokens.  → 0.0 when granted, else the seconds until
        enough tokens will have refilled (the honest Retry-After)."""
        if self.t_last is not None:
            self.tokens = min(self.burst,
                              self.tokens + (now - self.t_last) * self.rate)
        self.t_last = now
        if self.tokens >= n:
            self.tokens -= n
            return 0.0
        return (n - self.tokens) / self.rate


class FairQueue:
    """Bounded per-tenant FIFOs drained by deficit round robin."""

    def __init__(self, depth: int = 256, weights: Optional[dict] = None,
                 quantum: float = 1.0):
        self.depth = max(int(depth), 1)
        self.weights = dict(weights or {})
        self.quantum = float(quantum)
        self._queues: OrderedDict[str, deque] = OrderedDict()
        self._deficit: dict[str, float] = {}

    def weight(self, tenant: str) -> float:
        return max(float(self.weights.get(tenant, 1.0)), 1e-3)

    def push(self, tenant: str, item, *, force: bool = False) -> bool:
        """Enqueue; ``False`` when the tenant's FIFO is at depth (the
        caller sheds).  ``force`` exempts already-admitted work — e.g.
        the continuation pages of a streaming session, which must never
        be shed mid-stream."""
        q = self._queues.get(tenant)
        if q is None:
            q = deque()
            self._queues[tenant] = q
            self._deficit[tenant] = 0.0
        if not force and len(q) >= self.depth:
            return False
        q.append(item)
        return True

    def depth_of(self, tenant: str) -> int:
        q = self._queues.get(tenant)
        return len(q) if q is not None else 0

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def pop_batch(self, max_items: int) -> list:
        """Dequeue up to ``max_items`` as (tenant, item) pairs, DRR-fair
        across the tenants with pending work."""
        batch: list = []
        if max_items <= 0:
            return batch
        pending = True
        while len(batch) < max_items and pending:
            pending = False
            for tenant in list(self._queues):
                q = self._queues[tenant]
                if not q:
                    # empty queue forfeits its accumulated deficit (DRR:
                    # credit never carries across idle periods)
                    self._deficit[tenant] = 0.0
                    continue
                self._deficit[tenant] += self.quantum * self.weight(tenant)
                while q and self._deficit[tenant] >= 1.0 \
                        and len(batch) < max_items:
                    batch.append((tenant, q.popleft()))
                    self._deficit[tenant] -= 1.0
                if q:
                    pending = True
                if len(batch) >= max_items:
                    break
            # a nonempty queue accrues deficit every cycle, so the loop
            # always progresses toward either max_items or empty queues
            pending = pending or any(len(q) for q in self._queues.values())
            if not pending:
                break
        return batch


@dataclasses.dataclass
class AdmissionStats:
    admitted: int = 0
    shed_rate_limited: int = 0   # no token in the tenant's bucket
    shed_queue_full: int = 0     # tenant FIFO at depth
    forced: int = 0              # depth-exempt continuation work

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class AdmissionController:
    """Per-tenant token buckets in front of the weighted-fair queue."""

    def __init__(self, *, rate: float = 500.0, burst: float = 250.0,
                 depth: int = 256, weights: Optional[dict] = None,
                 quantum: float = 1.0, clock=time.monotonic):
        self.rate = rate
        self.burst = burst
        self.clock = clock
        self.queue = FairQueue(depth=depth, weights=weights, quantum=quantum)
        self.stats = AdmissionStats()
        self._buckets: dict[str, TokenBucket] = {}

    def bucket(self, tenant: str) -> TokenBucket:
        b = self._buckets.get(tenant)
        if b is None:
            b = TokenBucket(self.rate, self.burst)
            self._buckets[tenant] = b
        return b

    def charge(self, tenant: str, n: float = 1.0) -> None:
        """Debit the tenant's bucket without queueing (mutations and other
        directly-executed work).  Sheds with the exact refill wait."""
        wait = self.bucket(tenant).try_take(self.clock(), n)
        if wait > 0.0:
            self.stats.shed_rate_limited += 1
            raise RateLimitedError(
                f"tenant {tenant!r} over quota ({self.rate:g}/s, "
                f"burst {self.burst:g})", wait)

    def admit(self, tenant: str, item, *, force: bool = False) -> None:
        """Charge the bucket and enqueue, or shed with a 429-mapped
        error.  ``force`` bypasses both bounds (continuation work of an
        already-admitted request)."""
        if force:
            self.queue.push(tenant, item, force=True)
            self.stats.forced += 1
            return
        # capacity check before the bucket so a queue-full shed does not
        # also waste one of the tenant's tokens
        if self.queue.depth_of(tenant) >= self.queue.depth:
            self.stats.shed_queue_full += 1
            # time for the dispatcher to drain one slot, roughly: the
            # tenant's whole backlog over its fair admission rate
            retry = min(max(self.queue.depth / max(self.rate, 1.0), 0.05),
                        5.0)
            raise OverloadedError(
                f"tenant {tenant!r} queue full "
                f"(depth {self.queue.depth})", retry)
        self.charge(tenant)
        self.queue.push(tenant, item, force=True)
        self.stats.admitted += 1
