"""Interactive sessions: incremental top-k result delivery.

A session wraps any resumable ranking run presenting the uniform
``target / result / n`` surface — :class:`repro.core.engine.TopKRun` or
:class:`repro.core.engine.FilteredTopKRun` (a predicate-filtered ranking
paginates identically; the predicate residue just rides the same frontier).
The GUI's "LIMIT 25 → next 25" interaction becomes: raise the run's
finality target to ``served + k`` (re-deriving the pruning frontier from
the *cached* bounds — no new CHI pass) and run only the extra verification
batches the larger target needs.  Pagination over n pages therefore returns
exactly the ids/scores of a one-shot ``LIMIT n·k`` query, at a fraction of
fresh cost.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import OrderedDict
from typing import Optional

from .errors import NotFoundError

_session_counter = itertools.count(1)


@dataclasses.dataclass
class Session:
    id: str
    sql: str
    run: object                      # TopKRun | FilteredTopKRun
    page_size: int
    kind: str = "topk"
    served: int = 0
    pages_served: int = 0
    done: bool = False               # qualifying result set fully delivered
    created_s: float = dataclasses.field(default_factory=time.monotonic)
    last_used_s: float = dataclasses.field(default_factory=time.monotonic)

    @property
    def exhausted(self) -> bool:
        # ``done`` covers filtered rankings, whose deliverable count is the
        # number of predicate-qualifying rows — discovered during paging —
        # not the candidate count ``run.n``.
        return self.done or self.served >= self.run.n

    def page_bounds(self, k: Optional[int]) -> tuple[int, int]:
        k = self.page_size if k is None else max(int(k), 1)
        return self.served, min(self.served + k, self.run.n)

    def stats(self) -> dict:
        """Per-session progress + phase breakdown (DESIGN.md §10) — what
        ``/stats`` and ``session.stats()`` surface for each live session."""
        s = self.run.stats
        now = time.monotonic()
        return {
            "sql": self.sql[:200], "kind": self.kind,
            "served": self.served, "pages_served": self.pages_served,
            "total_candidates": self.run.n, "exhausted": self.exhausted,
            "age_s": now - self.created_s, "idle_s": now - self.last_used_s,
            "verified": s.n_verified, "bytes_loaded": s.bytes_loaded,
            "bytes_saved": s.bytes_saved,
            "phases": {"bounds_s": s.bound_time_s,
                       "verify_s": s.verify_time_s},
        }


class SessionManager:
    """Holds live sessions with LRU eviction beyond ``max_sessions``."""

    def __init__(self, max_sessions: int = 256):
        self.max_sessions = max_sessions
        self._sessions: OrderedDict[str, Session] = OrderedDict()
        self.created = 0
        self.evicted = 0

    def create(self, sql: str, run, page_size: int,
               kind: str = "topk") -> Session:
        sid = f"s{next(_session_counter)}-{id(run) & 0xffff:04x}"
        sess = Session(id=sid, sql=sql, run=run, kind=kind,
                       page_size=max(int(page_size), 1))
        self._sessions[sid] = sess
        self.created += 1
        while len(self._sessions) > self.max_sessions:
            self._sessions.popitem(last=False)
            self.evicted += 1
        return sess

    def get(self, sid: str) -> Session:
        sess = self._sessions.get(sid)
        if sess is None:
            # NotFoundError (a KeyError subclass) so the HTTP guards can
            # 404 this without treating every engine KeyError as 404.
            raise NotFoundError(f"unknown or expired session {sid!r}")
        self._sessions.move_to_end(sid)
        sess.last_used_s = time.monotonic()
        return sess

    def drop(self, sid: str) -> bool:
        return self._sessions.pop(sid, None) is not None

    def __len__(self) -> int:
        return len(self._sessions)

    def stats(self) -> dict:
        return {"active": len(self._sessions), "created": self.created,
                "evicted": self.evicted,
                "pages_served": sum(s.pages_served
                                    for s in self._sessions.values()),
                "per_session": {sid: s.stats()
                                for sid, s in self._sessions.items()}}
