"""MaskSearch interactive query service (DESIGN.md §5).

The serving layer between the SQL front-end and the engine: plan/result
caching, incremental top-k sessions, and cross-query fused verification —
the demo paper's interactive GUI loop as a subsystem.

Public surface:
  * :class:`MaskSearchService` — the stateful facade (:mod:`.api`).
  * :class:`ServiceClient`     — stdlib HTTP client (:mod:`.client`).
  * :func:`make_server` / ``python -m repro.service.server`` — HTTP front.
  * :class:`AsyncTier` / :func:`serve_in_thread` /
    ``python -m repro.service.asyncserver`` — the high-concurrency async
    front (admission control + cross-tenant batch fusion).
  * :mod:`.planner` / :mod:`.session` / :mod:`.scheduler` /
    :mod:`.routes` / :mod:`.admission` — the pieces.
"""

from .api import MaskSearchService  # noqa: F401
from .client import ServiceClient, ServiceError  # noqa: F401
from .planner import Planner, bounds_key, result_key, roi_signature  # noqa: F401
from .scheduler import FusedScheduler  # noqa: F401
from .session import Session, SessionManager  # noqa: F401


def __getattr__(name):
    # Lazy so `python -m repro.service.server` doesn't pre-import the module
    # through the package (runpy's double-import warning).
    if name == "make_server":
        from .server import make_server
        return make_server
    if name in ("AsyncTier", "serve_in_thread"):
        from . import asyncserver
        return getattr(asyncserver, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
