"""Pallas TPU kernels over the bitpacked binary-mask tier (DESIGN.md §12).

Binary masks are stored 1 bit/pixel as little-endian uint32 words
(core/packing.py), so the verification ops become bitwise AND/OR plus
popcount over ``(1, bh, words)`` tiles — the same streaming-reduction shape
as the float kernels at 1/32 the HBM traffic.  Four invariants make the
math exact and width-free:

* tail bits past ``W`` in a row's last word are zero (pack-time invariant),
* ROI column spans are already clipped to ``W`` (``cp.normalize_rois``),
* the ROI column predicate is a per-word *span mask* — for word ``k`` the
  uint32 with bits ``[clip(c0-32k, 0, 32), clip(c1-32k, 0, 32))`` set — so
  word-edge partial coverage costs one mask, not a per-bit test,
* on binary values the CP range test collapses to two flags:
  ``f1 = (lv <= 1 < uv)`` and ``f0 = (lv <= 0 < uv)``; the count inside the
  ROI is exactly ``f1·ones + f0·(area − ones)`` where ``ones`` is the
  popcount of ``mask & span`` and ``area`` the popcount of the span —
  bit-identical to the float kernel's ``(m >= lv) & (m < uv)`` sum.

Thresholded ops (pair / MASK_AGG, ``value > t`` on {0, 1}) build an
*effective word* per role: ``(t < 1 ? word : 0) | (t < 0 ? ~word : 0)``;
the complement's garbage tail bits are annihilated by the span mask at
count time.

``_fused_verify_popcount_kernel`` is the bounds+verify megakernel: one
launch takes the whole verification batch, every CP descriptor of the
plan, and the CHI verdicts (``decided``/``lb`` per (descriptor, mask)),
and emits exact counts — CHI-decided entries pass their bound through,
undecided ones are counted from the packed words.  That collapses the
Q-launches-per-batch float verify path to a single dispatch.

Kernel bodies are integer-only by construction; the ``popcount-no-float``
masklint rule enforces it (no float loads inside ``*_popcount_kernel``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .cp_count import _pick_bh

_WORD = 32


def _popcount32(x):
    """Bit-twiddle popcount of uint32 lanes → int32 (no f64, no LUTs)."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _mask_lt(n):
    """uint32 with bits [0, clip(n, 0, 32)) set, elementwise over int32 n."""
    shift = jnp.clip(n, 0, _WORD - 1).astype(jnp.uint32)
    partial = (jnp.uint32(1) << shift) - jnp.uint32(1)
    return jnp.where(n >= _WORD, jnp.uint32(0xFFFFFFFF), partial)


def _span_mask(lo, hi):
    """uint32 with bits [clip(lo,0,32), clip(hi,0,32)) set."""
    return _mask_lt(hi) & ~_mask_lt(lo)


def _effective_word(w, f1, f0):
    """Thresholded-binary word: bits where ``value > t`` holds given the
    flags ``f1 = (t < 1)``, ``f0 = (t < 0)`` (int32 0/1).  May carry tail
    garbage from the complement — AND with a span mask before counting."""
    zero = jnp.uint32(0)
    return jnp.where(f1 > 0, w, zero) | jnp.where(f0 > 0, ~w, zero)


def _range_flags(lv, uv):
    """CP range [lv, uv) on binary values → (f1, f0) int32 flags."""
    lv = jnp.asarray(lv, jnp.float32)
    uv = jnp.asarray(uv, jnp.float32)
    f1 = ((lv <= 1.0) & (1.0 < uv)).astype(jnp.int32)
    f0 = ((lv <= 0.0) & (0.0 < uv)).astype(jnp.int32)
    return f1, f0


def _thresh_flags(t):
    """``value > t`` on binary values → (f1, f0) int32 flags."""
    t = jnp.asarray(t, jnp.float32)
    return (t < 1.0).astype(jnp.int32), (t < 0.0).astype(jnp.int32)


def _tile_valid(roi_row, bh, nw, row_tile):
    """Per-word ROI coverage for one (bh, nw) tile: uint32 span masks on
    rows inside [r0, r1), zero elsewhere."""
    r0, c0, r1, c1 = roi_row[0], roi_row[1], roi_row[2], roi_row[3]
    rr = jax.lax.broadcasted_iota(jnp.int32, (bh, nw), 0) + row_tile * bh
    base = jax.lax.broadcasted_iota(jnp.int32, (bh, nw), 1) * _WORD
    span = _span_mask(c0 - base, c1 - base)
    return jnp.where((rr >= r0) & (rr < r1), span, jnp.uint32(0))


# ---------------------------------------------------------------------------
# Kernels
# ---------------------------------------------------------------------------


def _cp_popcount_kernel(roi_ref, f1_ref, f0_ref, mask_ref, out_ref, *,
                        bh: int, nw: int):
    row_tile = pl.program_id(1)

    @pl.when(row_tile == 0)
    def _init():
        out_ref[0] = 0

    m = mask_ref[0]                                   # (bh, nw) uint32
    valid = _tile_valid(roi_ref[0], bh, nw, row_tile)
    ones = jnp.sum(_popcount32(m & valid))
    area = jnp.sum(_popcount32(valid))
    out_ref[0] += f1_ref[0] * ones + f0_ref[0] * (area - ones)


def cp_count_packed_pallas(packed: jax.Array, rois: jax.Array, lv, uv, *,
                           interpret: bool = False) -> jax.Array:
    """(B, H, words) uint32, (B, 4) → (B,) int32 exact CP counts."""
    b, h, nw = packed.shape
    bh = _pick_bh(h, nw, packed.dtype.itemsize)
    grid = (b, h // bh)
    f1, f0 = _range_flags(lv, uv)
    kernel = functools.partial(_cp_popcount_kernel, bh=bh, nw=nw)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1, bh, nw), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=interpret,
    )(rois.astype(jnp.int32), f1.reshape(1), f0.reshape(1), packed)


def _cp_multi_popcount_kernel(rois_ref, f1s_ref, f0s_ref, mask_ref, out_ref,
                              *, bh: int, nw: int, q: int):
    row_tile = pl.program_id(1)

    @pl.when(row_tile == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m = mask_ref[0]                                   # (bh, nw) — loaded ONCE
    for qi in range(q):                               # static unroll over Q
        valid = _tile_valid(rois_ref[qi, 0], bh, nw, row_tile)
        ones = jnp.sum(_popcount32(m & valid))
        area = jnp.sum(_popcount32(valid))
        out_ref[qi, 0] += f1s_ref[qi] * ones + f0s_ref[qi] * (area - ones)


def cp_count_multi_packed_pallas(packed: jax.Array, rois: jax.Array,
                                 lvs: jax.Array, uvs: jax.Array, *,
                                 interpret: bool = False) -> jax.Array:
    """(B,H,words), (Q,B,4), (Q,), (Q,) → (Q,B) int32."""
    b, h, nw = packed.shape
    q = rois.shape[0]
    bh = _pick_bh(h, nw, packed.dtype.itemsize)
    grid = (b, h // bh)
    f1s, f0s = _range_flags(lvs, uvs)
    kernel = functools.partial(_cp_multi_popcount_kernel, bh=bh, nw=nw, q=q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q, 1, 4), lambda i, j: (0, i, 0)),
            pl.BlockSpec((q,), lambda i, j: (0,)),
            pl.BlockSpec((q,), lambda i, j: (0,)),
            pl.BlockSpec((1, bh, nw), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((q, 1), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((q, b), jnp.int32),
        interpret=interpret,
    )(rois.astype(jnp.int32), f1s, f0s, packed)


def _agg_popcount_kernel(roi_ref, f1_ref, f0_ref, masks_ref,
                         inter_ref, union_ref, *, bh: int, nw: int, s: int):
    row_tile = pl.program_id(1)

    @pl.when(row_tile == 0)
    def _init():
        inter_ref[0] = 0
        union_ref[0] = 0

    m = masks_ref[0]                                  # (S, bh, nw) uint32
    f1 = f1_ref[0]
    f0 = f0_ref[0]
    inter_w = _effective_word(m[0], f1, f0)
    union_w = inter_w
    for si in range(1, s):                            # static unroll over S
        eff = _effective_word(m[si], f1, f0)
        inter_w = inter_w & eff
        union_w = union_w | eff
    valid = _tile_valid(roi_ref[0], bh, nw, row_tile)
    inter_ref[0] += jnp.sum(_popcount32(inter_w & valid))
    union_ref[0] += jnp.sum(_popcount32(union_w & valid))


def mask_agg_counts_packed_pallas(group_packed: jax.Array, rois: jax.Array,
                                  thresh, *, interpret: bool = False):
    """(N, S, H, words), (N, 4), scalar → (inter (N,), union (N,)) int32."""
    n, s, h, nw = group_packed.shape
    bh = _pick_bh(h, nw, group_packed.dtype.itemsize,
                  budget_bytes=2 * 1024 * 1024 // max(s, 1))
    grid = (n, h // bh)
    f1, f0 = _thresh_flags(thresh)
    kernel = functools.partial(_agg_popcount_kernel, bh=bh, nw=nw, s=s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1, s, bh, nw), lambda i, j: (i, 0, j, 0)),
        ],
        out_specs=(pl.BlockSpec((1,), lambda i, j: (i,)),
                   pl.BlockSpec((1,), lambda i, j: (i,))),
        out_shape=(jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.int32)),
        interpret=interpret,
    )(rois.astype(jnp.int32), f1.reshape(1), f0.reshape(1), group_packed)


def _pair_popcount_kernel(roi_ref, fa_ref, fb_ref, a_ref, b_ref,
                          inter_ref, union_ref, diff_ref, *,
                          bh: int, nw: int):
    row_tile = pl.program_id(1)

    @pl.when(row_tile == 0)
    def _init():
        inter_ref[0] = 0
        union_ref[0] = 0
        diff_ref[0] = 0

    ea = _effective_word(a_ref[0], fa_ref[0], fa_ref[1])
    eb = _effective_word(b_ref[0], fb_ref[0], fb_ref[1])
    valid = _tile_valid(roi_ref[0], bh, nw, row_tile)
    inter_ref[0] += jnp.sum(_popcount32(ea & eb & valid))
    union_ref[0] += jnp.sum(_popcount32((ea | eb) & valid))
    diff_ref[0] += jnp.sum(_popcount32(ea & ~eb & valid))


def pair_counts_packed_pallas(packed_a: jax.Array, packed_b: jax.Array,
                              rois: jax.Array, ta, tb, *,
                              interpret: bool = False):
    """(B,H,words)×2, (B,4) → (inter, union, diff) each (B,) int32."""
    b, h, nw = packed_a.shape
    bh = _pick_bh(h, nw, packed_a.dtype.itemsize)
    grid = (b, h // bh)
    fa = jnp.stack(_thresh_flags(ta))
    fb = jnp.stack(_thresh_flags(tb))
    kernel = functools.partial(_pair_popcount_kernel, bh=bh, nw=nw)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
            pl.BlockSpec((1, bh, nw), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bh, nw), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=interpret,
    )(rois.astype(jnp.int32), fa, fb, packed_a, packed_b)
    return tuple(out)


def _fused_verify_popcount_kernel(rois_ref, f1s_ref, f0s_ref, dec_ref,
                                  lb_ref, mask_ref, out_ref, *,
                                  bh: int, nw: int, q: int):
    row_tile = pl.program_id(1)

    @pl.when(row_tile == 0)
    def _init():
        # CHI-decided (descriptor, mask) entries pass their exact bound
        # straight through; undecided entries start at 0 and accumulate.
        out_ref[...] = dec_ref[...] * lb_ref[...]

    m = mask_ref[0]                                   # (bh, nw) — loaded ONCE
    for qi in range(q):                               # static unroll over Q
        valid = _tile_valid(rois_ref[qi, 0], bh, nw, row_tile)
        ones = jnp.sum(_popcount32(m & valid))
        area = jnp.sum(_popcount32(valid))
        count = f1s_ref[qi] * ones + f0s_ref[qi] * (area - ones)
        out_ref[qi, 0] += (1 - dec_ref[qi, 0]) * count


def fused_verify_packed_pallas(packed: jax.Array, rois: jax.Array,
                               lvs: jax.Array, uvs: jax.Array,
                               decided: jax.Array, lb: jax.Array, *,
                               interpret: bool = False) -> jax.Array:
    """The bounds+verify megakernel: one launch per verification batch.

    (B,H,words), (Q,B,4), (Q,), (Q,), decided (Q,B) int32 0/1, lb (Q,B)
    int32 → (Q,B) int32 exact counts.  Where ``decided`` the CHI bound is
    already exact (lb == ub) and is emitted as-is; everywhere else the
    packed words are counted — all Q descriptors answered from a single
    pass over the batch's bits.
    """
    b, h, nw = packed.shape
    q = rois.shape[0]
    bh = _pick_bh(h, nw, packed.dtype.itemsize)
    grid = (b, h // bh)
    f1s, f0s = _range_flags(lvs, uvs)
    kernel = functools.partial(_fused_verify_popcount_kernel, bh=bh, nw=nw,
                               q=q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q, 1, 4), lambda i, j: (0, i, 0)),
            pl.BlockSpec((q,), lambda i, j: (0,)),
            pl.BlockSpec((q,), lambda i, j: (0,)),
            pl.BlockSpec((q, 1), lambda i, j: (0, i)),
            pl.BlockSpec((q, 1), lambda i, j: (0, i)),
            pl.BlockSpec((1, bh, nw), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((q, 1), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((q, b), jnp.int32),
        interpret=interpret,
    )(rois.astype(jnp.int32), f1s, f0s, decided.astype(jnp.int32),
      lb.astype(jnp.int32), packed)


# ---------------------------------------------------------------------------
# jnp references (portable fallbacks; ops.py dispatches here off-TPU)
# ---------------------------------------------------------------------------


def _pc(x):
    return jax.lax.population_count(x.astype(jnp.uint32)).astype(jnp.int32)


def _valid_words(rois, h, nw):
    """(B, 4) int32 → (B, h, nw) uint32 per-word ROI coverage masks."""
    b = rois.shape[0]
    rr = jax.lax.broadcasted_iota(jnp.int32, (b, h, nw), 1)
    base = jax.lax.broadcasted_iota(jnp.int32, (b, h, nw), 2) * _WORD
    r0 = rois[:, 0][:, None, None]
    c0 = rois[:, 1][:, None, None]
    r1 = rois[:, 2][:, None, None]
    c1 = rois[:, 3][:, None, None]
    span = _span_mask(c0 - base, c1 - base)
    return jnp.where((rr >= r0) & (rr < r1), span, jnp.uint32(0))


def cp_count_packed_ref(packed, rois, lv, uv):
    _, h, nw = packed.shape
    valid = _valid_words(rois.astype(jnp.int32), h, nw)
    ones = jnp.sum(_pc(packed & valid), axis=(1, 2))
    area = jnp.sum(_pc(valid), axis=(1, 2))
    f1, f0 = _range_flags(lv, uv)
    return (f1 * ones + f0 * (area - ones)).astype(jnp.int32)


def cp_count_multi_packed_ref(packed, rois, lvs, uvs):
    return jax.vmap(cp_count_packed_ref, in_axes=(None, 0, 0, 0))(
        packed, rois.astype(jnp.int32), lvs, uvs)


def mask_agg_counts_packed_ref(group_packed, rois, thresh):
    _, s, h, nw = group_packed.shape
    f1, f0 = _thresh_flags(thresh)
    inter_w = _effective_word(group_packed[:, 0], f1, f0)
    union_w = inter_w
    for si in range(1, s):
        eff = _effective_word(group_packed[:, si], f1, f0)
        inter_w = inter_w & eff
        union_w = union_w | eff
    valid = _valid_words(rois.astype(jnp.int32), h, nw)
    inter = jnp.sum(_pc(inter_w & valid), axis=(1, 2))
    union = jnp.sum(_pc(union_w & valid), axis=(1, 2))
    return inter, union


def pair_counts_packed_ref(packed_a, packed_b, rois, ta, tb):
    _, h, nw = packed_a.shape
    fa1, fa0 = _thresh_flags(ta)
    fb1, fb0 = _thresh_flags(tb)
    ea = _effective_word(packed_a, fa1, fa0)
    eb = _effective_word(packed_b, fb1, fb0)
    valid = _valid_words(rois.astype(jnp.int32), h, nw)
    inter = jnp.sum(_pc(ea & eb & valid), axis=(1, 2))
    union = jnp.sum(_pc((ea | eb) & valid), axis=(1, 2))
    diff = jnp.sum(_pc(ea & ~eb & valid), axis=(1, 2))
    return inter, union, diff


def fused_verify_packed_ref(packed, rois, lvs, uvs, decided, lb):
    counts = cp_count_multi_packed_ref(packed, rois, lvs, uvs)
    return jnp.where(decided.astype(jnp.int32) > 0,
                     lb.astype(jnp.int32), counts)
