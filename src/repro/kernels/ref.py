"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics; the kernels are the fast TPU implementations.
Tests sweep shapes/dtypes and assert exact (integer-count) agreement.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def _roi_mask(rois: Array, height: int, width: int) -> Array:
    rr = jax.lax.broadcasted_iota(jnp.int32, (1, height, width), 1)
    cc = jax.lax.broadcasted_iota(jnp.int32, (1, height, width), 2)
    r0, c0, r1, c1 = (rois[:, i][:, None, None] for i in range(4))
    return (rr >= r0) & (rr < r1) & (cc >= c0) & (cc < c1)


def cp_count_ref(masks: Array, rois: Array, lv, uv) -> Array:
    """(B, H, W), (B, 4), scalars → (B,) int32 — exact CP."""
    b, h, w = masks.shape
    inside = _roi_mask(rois, h, w)
    in_range = (masks >= lv) & (masks < uv)
    return jnp.sum(inside & in_range, axis=(1, 2)).astype(jnp.int32)


def chi_cell_hist_ref(masks: Array, edges: Array, grid: int) -> Array:
    """(B, H, W), interior edges (NB-1,) → (B, G, G, NB) int32 cell
    histograms.  Requires G | H and G | W (the kernel's contract; ragged
    geometry goes through core.chi.cell_histograms instead)."""
    b, h, w = masks.shape
    g = grid
    ch, cw = h // g, w // g
    nb = edges.shape[0] + 1
    bins = jnp.sum(masks[..., None] >= edges, axis=-1).astype(jnp.int32)
    onehot = jax.nn.one_hot(bins, nb, dtype=jnp.int32)       # (B,H,W,NB)
    x = onehot.reshape(b, g, ch, g, cw, nb)
    return x.sum(axis=(2, 4)).astype(jnp.int32)              # (B,G,G,NB)


def mask_agg_counts_ref(group_masks: Array, rois: Array, thresh) -> tuple[Array, Array]:
    """(N, S, H, W), (N, 4), scalar → (inter (N,), union (N,)) int32.

    Counts of the thresholded intersection / union inside each ROI — the
    fused MASK_AGG primitive behind IoU queries."""
    n, s, h, w = group_masks.shape
    binary = group_masks > thresh
    inter = jnp.all(binary, axis=1)
    union = jnp.any(binary, axis=1)
    inside = _roi_mask(rois, h, w)
    inter_ct = jnp.sum(inter & inside, axis=(1, 2)).astype(jnp.int32)
    union_ct = jnp.sum(union & inside, axis=(1, 2)).astype(jnp.int32)
    return inter_ct, union_ct


def pair_counts_ref(masks_a: Array, masks_b: Array, rois: Array,
                    ta, tb) -> tuple[Array, Array, Array]:
    """(B,H,W)×2, (B,4), scalars → (inter, union, diff) each (B,) int32.

    Counts of the thresholded intersection (A∩B), union (A∪B) and
    difference (A∖B) inside each pair's ROI — the dual-mask verification
    primitive behind IoU/discrepancy queries (one pass over both masks)."""
    _, h, w = masks_a.shape
    ba = masks_a > ta
    bb = masks_b > tb
    inside = _roi_mask(rois, h, w)
    inter = jnp.sum(inside & ba & bb, axis=(1, 2)).astype(jnp.int32)
    union = jnp.sum(inside & (ba | bb), axis=(1, 2)).astype(jnp.int32)
    diff = jnp.sum(inside & ba & ~bb, axis=(1, 2)).astype(jnp.int32)
    return inter, union, diff


def cp_count_multi_ref(masks: Array, rois: Array, lvs: Array, uvs: Array) -> Array:
    """(B,H,W), (Q,B,4), (Q,), (Q,) → (Q,B) int32 — the multi-query CP pass
    (one read of the mask bytes answers Q descriptors)."""
    def one(roi_q, lv_q, uv_q):
        return cp_count_ref(masks, roi_q, lv_q, uv_q)
    return jax.vmap(one)(rois, lvs, uvs)
