"""Pallas TPU kernel: batched CP (count-pixels-in-range-inside-ROI).

This is the engine's verification hot path: for every survivor mask, count
pixels whose value lies in ``[lv, uv)`` inside the mask's ROI.  It is a
bandwidth-bound streaming reduction — exactly the op the paper pays disk I/O
for; on TPU the cost is the HBM→VMEM stream, so the kernel's job is to touch
each mask byte exactly once with aligned tiles and keep everything else in
registers/VMEM.

Tiling: grid ``(B, H/bh)``; each step loads a ``(1, bh, W)`` VMEM tile (lane
dimension = W, kept whole so loads are 128-lane aligned for typical mask
widths; bh chosen so the tile is ≤ ~2 MiB).  The ROI predicate is built from
``broadcasted_iota`` offset by the grid position — no per-pixel index tensors
ever hit HBM.  Partial counts accumulate into the (1,)-blocked output across
the row-tile axis (sequential TPU grid ⇒ safe accumulation).

The ``(Q,)`` *multi-query* variant (`cp_count_multi`) reuses one tile load
for every descriptor in the workload — the paper's multi-query optimization
moved inside the kernel: arithmetic intensity rises from O(1) to O(Q) per
byte.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_bh(h: int, w: int, itemsize: int = 4,
             budget_bytes: int = 2 * 1024 * 1024) -> int:
    """Largest row-tile height that divides H and fits the VMEM budget.

    ``itemsize`` is the element byte width of the streamed tiles (float32
    masks and packed uint32 words are both 4, but the packed tier's ``w``
    is a *word* count — callers pass ``arr.dtype.itemsize`` so the budget
    math holds for any representation)."""
    max_rows = max(budget_bytes // max(w * max(itemsize, 1), 1), 1)
    bh = min(h, max_rows)
    while h % bh:
        bh -= 1
    return max(bh, 1)


def _cp_kernel(roi_ref, mask_ref, lv_ref, uv_ref, out_ref, *, bh: int, w: int):
    row_tile = pl.program_id(1)

    @pl.when(row_tile == 0)
    def _init():
        out_ref[0] = 0

    m = mask_ref[0]                                   # (bh, W)
    lv = lv_ref[0]
    uv = uv_ref[0]
    r0, c0, r1, c1 = roi_ref[0, 0], roi_ref[0, 1], roi_ref[0, 2], roi_ref[0, 3]
    rr = jax.lax.broadcasted_iota(jnp.int32, (bh, w), 0) + row_tile * bh
    cc = jax.lax.broadcasted_iota(jnp.int32, (bh, w), 1)
    inside = (rr >= r0) & (rr < r1) & (cc >= c0) & (cc < c1)
    in_range = (m >= lv) & (m < uv)
    out_ref[0] += jnp.sum((inside & in_range).astype(jnp.int32))


def cp_count_pallas(masks: jax.Array, rois: jax.Array, lv, uv, *,
                    interpret: bool = False) -> jax.Array:
    """(B, H, W), (B, 4) → (B,) int32.  See module docstring."""
    b, h, w = masks.shape
    bh = _pick_bh(h, w, masks.dtype.itemsize)
    grid = (b, h // bh)
    lv = jnp.asarray(lv, masks.dtype).reshape(1)
    uv = jnp.asarray(uv, masks.dtype).reshape(1)
    kernel = functools.partial(_cp_kernel, bh=bh, w=w)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bh, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=interpret,
    )(rois.astype(jnp.int32), masks, lv, uv)


def _cp_multi_kernel(rois_ref, lvs_ref, uvs_ref, mask_ref, out_ref, *,
                     bh: int, w: int, q: int):
    row_tile = pl.program_id(1)

    @pl.when(row_tile == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m = mask_ref[0]                                   # (bh, W) — loaded ONCE
    rr = jax.lax.broadcasted_iota(jnp.int32, (bh, w), 0) + row_tile * bh
    cc = jax.lax.broadcasted_iota(jnp.int32, (bh, w), 1)
    for qi in range(q):                               # static unroll over Q
        r0, c0 = rois_ref[qi, 0, 0], rois_ref[qi, 0, 1]
        r1, c1 = rois_ref[qi, 0, 2], rois_ref[qi, 0, 3]
        inside = (rr >= r0) & (rr < r1) & (cc >= c0) & (cc < c1)
        in_range = (m >= lvs_ref[qi]) & (m < uvs_ref[qi])
        out_ref[qi, 0] += jnp.sum((inside & in_range).astype(jnp.int32))


def cp_count_multi_pallas(masks: jax.Array, rois: jax.Array,
                          lvs: jax.Array, uvs: jax.Array, *,
                          interpret: bool = False) -> jax.Array:
    """(B,H,W), (Q,B,4), (Q,), (Q,) → (Q,B) int32 — Q descriptors per tile load."""
    b, h, w = masks.shape
    q = rois.shape[0]
    bh = _pick_bh(h, w, masks.dtype.itemsize)
    grid = (b, h // bh)
    kernel = functools.partial(_cp_multi_kernel, bh=bh, w=w, q=q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q, 1, 4), lambda i, j: (0, i, 0)),
            pl.BlockSpec((q,), lambda i, j: (0,)),
            pl.BlockSpec((q,), lambda i, j: (0,)),
            pl.BlockSpec((1, bh, w), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((q, 1), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((q, b), jnp.int32),
        interpret=interpret,
    )(rois.astype(jnp.int32), lvs.astype(masks.dtype), uvs.astype(masks.dtype),
      masks)
