"""Pallas TPU kernel: fused dual-mask pair counts.

The dual-mask query class (saliency-vs-attention discrepancy, DESIGN.md §9)
verifies per-image *pairs*: threshold mask A at ``ta``, mask B at ``tb``,
and count, inside the pair's ROI, the pixels of the intersection (A∩B), the
union (A∪B) and the difference (A∖B).  IoU and every other pair statistic
the plan IR can express derive from these three counts, so the kernel
computes all of them in **one pass over both masks** — each byte of either
mask is streamed HBM→VMEM exactly once per verification batch, the same
budget a single-mask CP pays.

Tiling mirrors ``cp_count``: grid ``(B, H/bh)``; each step loads one
``(1, bh, W)`` tile of each mask (lane dimension = W kept whole), builds
the ROI predicate from ``broadcasted_iota``, and accumulates the three
counts into (1,)-blocked outputs across the sequential row-tile axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .cp_count import _pick_bh


def _pair_kernel(roi_ref, a_ref, b_ref, ta_ref, tb_ref,
                 inter_ref, union_ref, diff_ref, *, bh: int, w: int):
    row_tile = pl.program_id(1)

    @pl.when(row_tile == 0)
    def _init():
        inter_ref[0] = 0
        union_ref[0] = 0
        diff_ref[0] = 0

    a = a_ref[0]                                      # (bh, W)
    b = b_ref[0]
    ba = a > ta_ref[0]
    bb = b > tb_ref[0]
    r0, c0, r1, c1 = roi_ref[0, 0], roi_ref[0, 1], roi_ref[0, 2], roi_ref[0, 3]
    rr = jax.lax.broadcasted_iota(jnp.int32, (bh, w), 0) + row_tile * bh
    cc = jax.lax.broadcasted_iota(jnp.int32, (bh, w), 1)
    inside = (rr >= r0) & (rr < r1) & (cc >= c0) & (cc < c1)
    inter_ref[0] += jnp.sum((inside & ba & bb).astype(jnp.int32))
    union_ref[0] += jnp.sum((inside & (ba | bb)).astype(jnp.int32))
    diff_ref[0] += jnp.sum((inside & ba & ~bb).astype(jnp.int32))


def pair_counts_pallas(masks_a: jax.Array, masks_b: jax.Array,
                       rois: jax.Array, ta, tb, *,
                       interpret: bool = False):
    """(B,H,W)×2, (B,4) → (inter, union, diff) each (B,) int32.

    ``diff`` is |A∖B| inside the ROI; |B∖A| is the same call with the roles
    swapped (the expression layer normalizes that at parse time).
    """
    b, h, w = masks_a.shape
    bh = _pick_bh(h, w, masks_a.dtype.itemsize)
    grid = (b, h // bh)
    ta = jnp.asarray(ta, masks_a.dtype).reshape(1)
    tb = jnp.asarray(tb, masks_b.dtype).reshape(1)
    kernel = functools.partial(_pair_kernel, bh=bh, w=w)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bh, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bh, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32),
        ],
        interpret=interpret,
    )(rois.astype(jnp.int32), masks_a, masks_b, ta, tb)
    return tuple(out)
