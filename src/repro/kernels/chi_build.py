"""Pallas TPU kernel: CHI construction (per-cell per-bin histograms).

Index ingest is a one-pass streaming histogram: for each mask, for each of
the G×G spatial cells, count pixels per value bin.  The kernel processes one
**row of cells** per grid step — a ``(1, ch, W)`` VMEM tile (full lane width)
— and turns the per-cell segmentation into an MXU matmul instead of a
scatter:

    for bin k:   inr   = (m >= e_k) & (m < e_{k+1})          # (ch, W) VPU
                 rowct = sum_rows(inr)                       # (1, W)  VPU
                 cells = rowct @ SEL                         # (1, G)  MXU

where ``SEL[w, g] = [w // cw == g]`` is an iota-built block-diagonal selector
living entirely in VMEM.  TPUs have no fast scatter; the selector matmul is
the TPU-native segment-sum (DESIGN.md §3, "hardware adaptation").

The cheap prefix sums that turn cell histograms into the CHI table stay in
XLA (``core.chi.histograms_to_table``) where they fuse freely.

Contract: G | H and G | W (production mask stores are padded to this); the
ragged path falls back to the jnp reference in ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chi_kernel(edges_ref, mask_ref, out_ref, *, ch: int, w: int, g: int,
                nb: int):
    m = mask_ref[0]                                       # (ch, W)
    cw = w // g
    # Block-diagonal selector, built from iota (never touches HBM).
    col = jax.lax.broadcasted_iota(jnp.int32, (w, g), 0)
    grp = jax.lax.broadcasted_iota(jnp.int32, (w, g), 1)
    sel = (col // cw == grp).astype(jnp.float32)          # (W, G)

    outs = []
    for k in range(nb):                                    # static unroll
        lo = edges_ref[k]
        hi = edges_ref[k + 1]
        inr = ((m >= lo) & (m < hi)).astype(jnp.float32)   # (ch, W)
        rowct = jnp.sum(inr, axis=0, keepdims=True)        # (1, W)
        cells = jnp.dot(rowct, sel,
                        preferred_element_type=jnp.float32)  # (1, G)
        outs.append(cells[0])
    out_ref[0, 0] = jnp.stack(outs, axis=1).astype(jnp.int32)  # (G, NB)


def chi_cell_hist_pallas(masks: jax.Array, interior_edges: jax.Array,
                         grid: int, *, interpret: bool = False) -> jax.Array:
    """(B, H, W), interior edges (NB-1,) → (B, G, G, NB) int32.

    ``interior_edges`` are the finite thresholds; ±inf sentinels are added
    here so the kernel's bin ranges cover the whole real line (matching
    core.chi semantics for out-of-[0,1) pixel values).
    """
    b, h, w = masks.shape
    g = grid
    if h % g or w % g:
        raise ValueError(f"chi_build kernel needs G|H and G|W, got {h}x{w}, G={g}")
    ch = h // g
    nb = interior_edges.shape[0] + 1
    big = jnp.asarray(jnp.finfo(masks.dtype).max, masks.dtype)
    edges = jnp.concatenate([
        jnp.asarray([-big], masks.dtype),
        interior_edges.astype(masks.dtype),
        jnp.asarray([big], masks.dtype),
    ])
    kernel = functools.partial(_chi_kernel, ch=ch, w=w, g=g, nb=nb)
    return pl.pallas_call(
        kernel,
        grid=(b, g),
        in_specs=[
            pl.BlockSpec((nb + 1,), lambda i, j: (0,)),
            pl.BlockSpec((1, ch, w), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, nb), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, g, g, nb), jnp.int32),
        interpret=interpret,
    )(edges, masks)
