"""Jit'd public wrappers for the Pallas kernels, with portable fallbacks.

Dispatch policy: the Pallas path is used on TPU backends (or when
``interpret=True`` is forced, e.g. in tests); every other backend gets the
pure-jnp reference, which is semantically identical.  Shape contracts that
the kernels can't serve (ragged CHI grids) also fall back.

Setting ``REPRO_FORCE_PALLAS_INTERPRET=1`` in the environment forces every
wrapper onto the Pallas path in interpret mode — CI uses this to exercise
the actual kernel bodies on CPU machines instead of only the jnp
references.

These wrappers are what core/ and the distributed engine call — nothing else
imports the kernel modules directly.
"""

from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp

from ..obs.metrics import REGISTRY as _REG
from . import popcount, ref
from .chi_build import chi_cell_hist_pallas
from .cp_count import cp_count_multi_pallas, cp_count_pallas
from .mask_agg import mask_agg_counts_pallas
from .pair_count import pair_counts_pallas

_FORCE_INTERPRET = os.environ.get("REPRO_FORCE_PALLAS_INTERPRET", "") == "1"

_KERNEL_LAUNCHES = _REG.counter(
    "masksearch_kernel_launches_total",
    "Dispatches through each public kernel wrapper", ("kernel",))
_KERNEL_SECONDS = _REG.histogram(
    "masksearch_kernel_dispatch_seconds",
    "Wall time per kernel wrapper dispatch (first call includes trace+jit "
    "compile; steady-state is the launch itself)", ("kernel",))
_JIT_COMPILES = _REG.counter(
    "masksearch_jit_compiles_total",
    "jit cache-entry growth observed per wrapper — a steadily rising count "
    "means shape/static-arg churn is defeating the jit cache", ("kernel",))


def _instrument(name: str, fn):
    """Wrap a jitted kernel entry point with launch counting, dispatch
    timing, and recompile detection (via the jit cache-size delta, absent
    on older jax — then the compile counter just stays 0)."""
    launches = _KERNEL_LAUNCHES.labels(kernel=name)
    seconds = _KERNEL_SECONDS.labels(kernel=name)
    compiles = _JIT_COMPILES.labels(kernel=name)

    def _cache_size() -> int:
        sz = getattr(fn, "_cache_size", None)
        try:
            return int(sz()) if callable(sz) else -1
        except Exception:
            return -1

    @functools.wraps(fn)
    def wrapper(*args, **kw):
        before = _cache_size()
        t0 = time.perf_counter()
        try:
            return fn(*args, **kw)
        finally:
            seconds.observe(time.perf_counter() - t0)
            launches.inc()
            after = _cache_size()
            if 0 <= before < after:
                compiles.inc(after - before)

    return wrapper


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _dispatch(use_pallas: bool | None, interpret: bool) -> tuple[bool, bool]:
    """Resolve the (pallas, interpret) pair for one wrapper call.

    The force flag only overrides the *default* dispatch — a caller that
    explicitly asked for the jnp reference (``use_pallas=False``) keeps it,
    so reference-vs-Pallas comparison tests stay meaningful under the
    forced-interpret CI leg."""
    if _FORCE_INTERPRET and use_pallas is None:
        return True, True
    pallas = _on_tpu() if use_pallas is None else use_pallas
    return pallas, interpret


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def cp_count(masks, rois, lv, uv, *, use_pallas: bool | None = None,
             interpret: bool = False):
    """Batched exact CP — (B,H,W), (B,4) → (B,) int32."""
    pallas, interpret = _dispatch(use_pallas, interpret)
    if pallas or interpret:
        return cp_count_pallas(masks, rois, lv, uv,
                               interpret=interpret or not _on_tpu())
    return ref.cp_count_ref(masks, rois, lv, uv)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def cp_count_multi(masks, rois, lvs, uvs, *, use_pallas: bool | None = None,
                   interpret: bool = False):
    """Multi-query CP — (B,H,W), (Q,B,4), (Q,), (Q,) → (Q,B) int32."""
    pallas, interpret = _dispatch(use_pallas, interpret)
    if pallas or interpret:
        return cp_count_multi_pallas(masks, rois, lvs, uvs,
                                     interpret=interpret or not _on_tpu())
    return ref.cp_count_multi_ref(masks, rois, lvs, uvs)


@functools.partial(jax.jit, static_argnames=("grid", "use_pallas", "interpret"))
def chi_cell_hist(masks, interior_edges, grid: int, *,
                  use_pallas: bool | None = None, interpret: bool = False):
    """CHI ingest histograms — (B,H,W) → (B,G,G,NB) int32."""
    _, h, w = masks.shape
    divisible = (h % grid == 0) and (w % grid == 0)
    pallas, interpret = _dispatch(use_pallas, interpret)
    pallas = pallas and divisible
    if (pallas or interpret) and divisible:
        return chi_cell_hist_pallas(masks, interior_edges, grid,
                                    interpret=interpret or not _on_tpu())
    return ref.chi_cell_hist_ref(masks, interior_edges, grid)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def mask_agg_counts(group_masks, rois, thresh, *,
                    use_pallas: bool | None = None, interpret: bool = False):
    """Fused MASK_AGG counts — (N,S,H,W), (N,4) → (inter, union) int32."""
    pallas, interpret = _dispatch(use_pallas, interpret)
    if pallas or interpret:
        return mask_agg_counts_pallas(group_masks, rois, thresh,
                                      interpret=interpret or not _on_tpu())
    return ref.mask_agg_counts_ref(group_masks, rois, thresh)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def pair_counts(masks_a, masks_b, rois, ta, tb, *,
                use_pallas: bool | None = None, interpret: bool = False):
    """Fused dual-mask counts — (B,H,W)×2, (B,4) → (inter, union, diff),
    each (B,) int32, in one pass over both masks (DESIGN.md §9)."""
    pallas, interpret = _dispatch(use_pallas, interpret)
    if pallas or interpret:
        return pair_counts_pallas(masks_a, masks_b, rois, ta, tb,
                                  interpret=interpret or not _on_tpu())
    return ref.pair_counts_ref(masks_a, masks_b, rois, ta, tb)


# -- bitpacked binary-mask tier (DESIGN.md §12) -----------------------------


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def cp_count_packed(packed, rois, lv, uv, *, use_pallas: bool | None = None,
                    interpret: bool = False):
    """Batched exact CP on packed words — (B,H,words) uint32, (B,4) →
    (B,) int32, bit-identical to ``cp_count`` on the same binary masks."""
    pallas, interpret = _dispatch(use_pallas, interpret)
    if pallas or interpret:
        return popcount.cp_count_packed_pallas(
            packed, rois, lv, uv, interpret=interpret or not _on_tpu())
    return popcount.cp_count_packed_ref(packed, rois, lv, uv)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def cp_count_multi_packed(packed, rois, lvs, uvs, *,
                          use_pallas: bool | None = None,
                          interpret: bool = False):
    """Multi-query CP on packed words — (B,H,words), (Q,B,4) → (Q,B)."""
    pallas, interpret = _dispatch(use_pallas, interpret)
    if pallas or interpret:
        return popcount.cp_count_multi_packed_pallas(
            packed, rois, lvs, uvs, interpret=interpret or not _on_tpu())
    return popcount.cp_count_multi_packed_ref(packed, rois, lvs, uvs)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def mask_agg_counts_packed(group_packed, rois, thresh, *,
                           use_pallas: bool | None = None,
                           interpret: bool = False):
    """Fused MASK_AGG counts on packed words — (N,S,H,words), (N,4) →
    (inter, union) int32."""
    pallas, interpret = _dispatch(use_pallas, interpret)
    if pallas or interpret:
        return popcount.mask_agg_counts_packed_pallas(
            group_packed, rois, thresh, interpret=interpret or not _on_tpu())
    return popcount.mask_agg_counts_packed_ref(group_packed, rois, thresh)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def pair_counts_packed(packed_a, packed_b, rois, ta, tb, *,
                       use_pallas: bool | None = None,
                       interpret: bool = False):
    """Fused dual-mask counts on packed words — (B,H,words)×2, (B,4) →
    (inter, union, diff), each (B,) int32."""
    pallas, interpret = _dispatch(use_pallas, interpret)
    if pallas or interpret:
        return popcount.pair_counts_packed_pallas(
            packed_a, packed_b, rois, ta, tb,
            interpret=interpret or not _on_tpu())
    return popcount.pair_counts_packed_ref(packed_a, packed_b, rois, ta, tb)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def fused_bounds_verify(packed, rois, lvs, uvs, decided, lb, *,
                        use_pallas: bool | None = None,
                        interpret: bool = False):
    """Bounds+verify megakernel — one launch answers every CP descriptor
    of a verification batch, passing CHI-decided entries through and
    counting the undecided remainder from the packed words.  (B,H,words),
    (Q,B,4), (Q,), (Q,), (Q,B), (Q,B) → (Q,B) int32."""
    pallas, interpret = _dispatch(use_pallas, interpret)
    if pallas or interpret:
        return popcount.fused_verify_packed_pallas(
            packed, rois, lvs, uvs, decided, lb,
            interpret=interpret or not _on_tpu())
    return popcount.fused_verify_packed_ref(packed, rois, lvs, uvs,
                                            decided, lb)


cp_count = _instrument("cp_count", cp_count)
cp_count_multi = _instrument("cp_count_multi", cp_count_multi)
chi_cell_hist = _instrument("chi_cell_hist", chi_cell_hist)
mask_agg_counts = _instrument("mask_agg_counts", mask_agg_counts)
pair_counts = _instrument("pair_counts", pair_counts)
cp_count_packed = _instrument("cp_count_packed", cp_count_packed)
cp_count_multi_packed = _instrument("cp_count_multi_packed",
                                    cp_count_multi_packed)
mask_agg_counts_packed = _instrument("mask_agg_counts_packed",
                                     mask_agg_counts_packed)
pair_counts_packed = _instrument("pair_counts_packed", pair_counts_packed)
fused_bounds_verify = _instrument("fused_bounds_verify", fused_bounds_verify)


def mask_agg_iou(group_masks, rois, thresh, **kw):
    """IoU per group from the fused counts."""
    inter, union = mask_agg_counts(group_masks, rois, thresh, **kw)
    return jnp.where(union > 0,
                     inter.astype(jnp.float32) / jnp.maximum(union, 1),
                     0.0)
