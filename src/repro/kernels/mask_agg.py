"""Pallas TPU kernel: fused MASK_AGG (thresholded intersection/union counts).

Scenario-3 IoU queries aggregate the masks of one image (model saliency +
human attention), threshold them, and count intersection/union pixels inside
an ROI.  Materializing the binary masks costs 2× the mask bytes in HBM
traffic; this kernel fuses threshold → AND/OR-reduce-over-types → ROI mask →
count into one pass, emitting two scalars per group.

Tiling: grid ``(N, H/bh)``; block ``(1, S, bh, W)`` — all S member masks of a
group stream together (S is small: 2–8 mask types).  Intersection is a min-
reduce over the type axis, union a max-reduce; both stay in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .cp_count import _pick_bh


def _agg_kernel(roi_ref, thresh_ref, masks_ref, inter_ref, union_ref, *,
                bh: int, w: int):
    row_tile = pl.program_id(1)

    @pl.when(row_tile == 0)
    def _init():
        inter_ref[0] = 0
        union_ref[0] = 0

    m = masks_ref[0]                                   # (S, bh, W)
    t = thresh_ref[0]
    binary = (m > t).astype(jnp.int32)
    inter = jnp.min(binary, axis=0)                    # AND over mask types
    union = jnp.max(binary, axis=0)                    # OR  over mask types
    r0, c0, r1, c1 = roi_ref[0, 0], roi_ref[0, 1], roi_ref[0, 2], roi_ref[0, 3]
    rr = jax.lax.broadcasted_iota(jnp.int32, (bh, w), 0) + row_tile * bh
    cc = jax.lax.broadcasted_iota(jnp.int32, (bh, w), 1)
    inside = ((rr >= r0) & (rr < r1) & (cc >= c0) & (cc < c1)).astype(jnp.int32)
    inter_ref[0] += jnp.sum(inter * inside)
    union_ref[0] += jnp.sum(union * inside)


def mask_agg_counts_pallas(group_masks: jax.Array, rois: jax.Array, thresh, *,
                           interpret: bool = False):
    """(N, S, H, W), (N, 4), scalar → (inter (N,), union (N,)) int32."""
    n, s, h, w = group_masks.shape
    bh = _pick_bh(h, w, group_masks.dtype.itemsize,
                  budget_bytes=2 * 1024 * 1024 // max(s, 1))
    grid = (n, h // bh)
    thresh = jnp.asarray(thresh, group_masks.dtype).reshape(1)
    kernel = functools.partial(_agg_kernel, bh=bh, w=w)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1, s, bh, w), lambda i, j: (i, 0, j, 0)),
        ],
        out_specs=(pl.BlockSpec((1,), lambda i, j: (i,)),
                   pl.BlockSpec((1,), lambda i, j: (i,))),
        out_shape=(jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.int32)),
        interpret=interpret,
    )(rois.astype(jnp.int32), thresh, group_masks)
