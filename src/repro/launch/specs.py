"""ShapeDtypeStruct input specs for every (arch × shape) dry-run cell.

``build_cell(cfg, shape_id, mesh, opt_cfg)`` returns a :class:`Cell` holding
the step function and fully-sharded abstract inputs — lower + compile happens
in dryrun.py.  No arrays are ever allocated (shannon/kernels pattern:
weak-type-correct ShapeDtypeStructs with NamedShardings attached).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import SHAPES, ModelConfig
from ..models import build_model
from ..train.optimizer import OptConfig, init_opt_state
from ..train.train_loop import make_train_step
from . import sharding as sh


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


@dataclasses.dataclass
class Cell:
    arch: str
    shape_id: str
    kind: str                      # train | prefill | decode
    step_fn: Callable              # already jit-wrapped with shardings
    args: tuple                    # abstract inputs (ShapeDtypeStruct trees)
    n_groups: int                  # scan trip count (for cost linearization)
    model_flops: float
    low_mem_opt: bool = False
    note: str = ""


def _opt_cfg_for(cfg: ModelConfig) -> OptConfig:
    # ≥100B-param MoE cells use the low-mem optimizer policy (DESIGN.md §6)
    big = cfg.num_experts >= 64 and cfg.d_model >= 5000
    if big:
        return OptConfig(moments_dtype="bfloat16", use_master=False)
    return OptConfig()


def abstract_init(model):
    """(params ShapeDtypeStruct tree, logical axes tree) — no allocation.
    Works because Param is a pytree node whose axes are static aux data:
    eval_shape keeps them intact while abstracting values."""
    from ..models.layers import split_params
    tree_shape = jax.eval_shape(model.init_tree, jax.random.PRNGKey(0))
    return split_params(tree_shape)


def _train_state_specs(model, cfg, mesh, opt_cfg):
    params_shape, axes = abstract_init(model)
    p_shard = sh.param_sharding_tree(mesh, params_shape, axes, cfg)
    params_sds = jax.tree.map(
        lambda s, d: _sds(s.shape, s.dtype, d), params_shape, p_shard)
    opt_shape = jax.eval_shape(
        functools.partial(init_opt_state, cfg=opt_cfg), params_shape)
    rep = sh.replicated(mesh)

    def like_params(tree_shape):
        shard = sh.param_sharding_tree(mesh, tree_shape, axes, cfg)
        return jax.tree.map(lambda s, d: _sds(s.shape, s.dtype, d),
                            tree_shape, shard)

    opt_sds = type(opt_shape)(
        step=_sds(opt_shape.step.shape, opt_shape.step.dtype, rep),
        mu=like_params(opt_shape.mu),
        nu=like_params(opt_shape.nu),
        master=(like_params(opt_shape.master)
                if opt_cfg.use_master else ()),
    )
    return params_sds, opt_sds


def _batch_specs(cfg: ModelConfig, kind: str, seq_len: int, batch: int,
                 mesh) -> dict:
    i32, f32 = jnp.int32, jnp.float32
    if cfg.is_encoder_decoder:
        dec = min(cfg.max_decode_len, seq_len)
        shapes = {
            "audio_feats": jax.ShapeDtypeStruct((batch, seq_len, cfg.d_model),
                                                f32),
            "tokens": jax.ShapeDtypeStruct((batch, dec), i32),
            "labels": jax.ShapeDtypeStruct((batch, dec), i32),
        }
    else:
        text_len = seq_len - (cfg.num_patches or 0)
        shapes = {
            "tokens": jax.ShapeDtypeStruct((batch, text_len), i32),
            "labels": jax.ShapeDtypeStruct((batch, text_len), i32),
        }
        if cfg.num_patches:
            shapes["patches"] = jax.ShapeDtypeStruct(
                (batch, cfg.num_patches, cfg.d_model), f32)
        if cfg.mtp_depth:
            shapes["labels_mtp"] = jax.ShapeDtypeStruct((batch, text_len), i32)
    if kind == "prefill":
        shapes.pop("labels", None)
        shapes.pop("labels_mtp", None)
    shard = sh.batch_sharding_tree(mesh, shapes, cfg)
    return jax.tree.map(lambda s, d: _sds(s.shape, s.dtype, d), shapes, shard)


def build_cell(arch: str, cfg: ModelConfig, shape_id: str, mesh,
               *, microbatches: Optional[int] = None) -> Cell:
    from ..roofline.extract import model_flops_for

    spec = SHAPES[shape_id]
    kind, seq_len, batch = spec["kind"], spec["seq_len"], spec["global_batch"]
    model = build_model(cfg)
    opt_cfg = _opt_cfg_for(cfg)
    mf = model_flops_for(cfg, kind, seq_len, batch)
    n_groups = (cfg.dec_layers if cfg.is_encoder_decoder else
                max(cfg.num_groups, 1))

    if kind == "train":
        mb = microbatches if microbatches is not None else \
            cfg.microbatches_train_4k
        if (cfg.prefer_pure_dp and "pod" in mesh.axis_names
                and microbatches is None):
            # multi-pod keeps the TP mapping (sharding.rules_for), so the
            # pure-DP mb=1 choice no longer holds — re-enable accumulation
            mb = max(mb, 4)
        params_sds, opt_sds = _train_state_specs(model, cfg, mesh, opt_cfg)
        batch_sds = _batch_specs(cfg, kind, seq_len, batch, mesh)
        step = make_train_step(
            model, opt_cfg, microbatches=mb,
            param_shardings=jax.tree.map(lambda s: s.sharding, params_sds))
        out_shardings = (jax.tree.map(lambda s: s.sharding, params_sds),
                         jax.tree.map(lambda s: s.sharding, opt_sds),
                         None)
        jitted = jax.jit(
            step,
            in_shardings=(jax.tree.map(lambda s: s.sharding, params_sds),
                          jax.tree.map(lambda s: s.sharding, opt_sds),
                          jax.tree.map(lambda s: s.sharding, batch_sds)),
            out_shardings=out_shardings,
            donate_argnums=(0, 1))
        return Cell(arch, shape_id, kind, jitted,
                    (params_sds, opt_sds, batch_sds), n_groups, mf,
                    low_mem_opt=not opt_cfg.use_master)

    # serving cells ---------------------------------------------------------
    params_shape, axes = abstract_init(model)
    p_shard = sh.param_sharding_tree(mesh, params_shape, axes, cfg)
    params_sds = jax.tree.map(lambda s, d: _sds(s.shape, s.dtype, d),
                              params_shape, p_shard)

    if cfg.is_encoder_decoder:
        cache_shape = jax.eval_shape(
            functools.partial(model.init_cache, batch, enc_len=seq_len))
    else:
        cache_shape = jax.eval_shape(
            functools.partial(model.init_cache, batch, seq_len))
    c_shard = sh.cache_sharding_tree(mesh, cache_shape)
    cache_sds = jax.tree.map(lambda s, d: _sds(s.shape, s.dtype, d),
                             cache_shape, c_shard)

    if kind == "prefill":
        batch_sds = _batch_specs(cfg, kind, seq_len, batch, mesh)
        jitted = jax.jit(
            model.prefill,
            in_shardings=(jax.tree.map(lambda s: s.sharding, params_sds),
                          jax.tree.map(lambda s: s.sharding, batch_sds),
                          jax.tree.map(lambda s: s.sharding, cache_sds)),
            donate_argnums=(2,))
        return Cell(arch, shape_id, kind, jitted,
                    (params_sds, batch_sds, cache_sds), n_groups, mf)

    # decode
    tok_shard = sh.batch_sharding_tree(
        mesh, {"t": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}, cfg)["t"]
    token_sds = _sds((batch, 1), jnp.int32, tok_shard)
    pos_sds = _sds((), jnp.int32, sh.replicated(mesh))
    jitted = jax.jit(
        model.decode_step,
        in_shardings=(jax.tree.map(lambda s: s.sharding, params_sds),
                      jax.tree.map(lambda s: s.sharding, cache_sds),
                      tok_shard, sh.replicated(mesh)),
        donate_argnums=(1,))
    return Cell(arch, shape_id, kind, jitted,
                (params_sds, cache_sds, token_sds, pos_sds), n_groups, mf)


# ---------------------------------------------------------------------------
# MaskSearch query-engine cells (the paper's technique on the same meshes)
# ---------------------------------------------------------------------------

MS_DB = dict(n_masks=1 << 22, height=256, width=256, grid=16, num_bins=16,
             verify_batch=1 << 16, groups=1 << 18, group_size=2)


def build_masksearch_cells(mesh) -> list[Cell]:
    from ..core import chi as chi_lib
    from ..core import distributed as dist

    cfg = chi_lib.CHIConfig(grid=MS_DB["grid"], num_bins=MS_DB["num_bins"],
                            height=MS_DB["height"], width=MS_DB["width"])
    eng_axes = tuple(mesh.axis_names)
    n = MS_DB["n_masks"]
    g1 = cfg.grid + 1
    nb1 = cfg.num_bins + 1
    row4 = NamedSharding(mesh, P(eng_axes, None, None, None))
    row2 = NamedSharding(mesh, P(eng_axes, None))
    row1 = NamedSharding(mesh, P(eng_axes))
    rep = NamedSharding(mesh, P())

    cells = []
    tables = _sds((n, g1, g1, nb1), jnp.int32, row4)
    rois = _sds((n, 4), jnp.int32, row2)
    rb = _sds((g1,), jnp.int32, rep)
    cb = _sds((g1,), jnp.int32, rep)
    vks = _sds((4,), jnp.int32, rep)
    thr = _sds((), jnp.int32, rep)

    cells.append(Cell("masksearch", "filter_bounds_4m", "query",
                      dist.make_filter_bounds_step(mesh, "<"),
                      (tables, rois, rb, cb, vks, thr), 1, 0.0,
                      note="CHI bounds+verdicts over 4.2M-mask DB"))

    topk_fn, _ = dist.make_topk_step(mesh, k=64, desc=True)
    ids = _sds((n,), jnp.int32, row1)
    cells.append(Cell("masksearch", "topk_bounds_4m", "query",
                      jax.jit(topk_fn), (tables, rois, rb, cb, vks, ids), 1,
                      0.0, note="distributed top-k candidate selection"))

    v = MS_DB["verify_batch"]
    masks = _sds((v, cfg.height, cfg.width), jnp.float32,
                 NamedSharding(mesh, P(eng_axes, None, None)))
    vrois = _sds((v, 4), jnp.int32, row2)
    lv = _sds((), jnp.float32, rep)
    uv = _sds((), jnp.float32, rep)
    cells.append(Cell("masksearch", "verify_64k", "query",
                      dist.make_verify_step(mesh), (masks, vrois, lv, uv), 1,
                      0.0, note="exact-CP verification round (64k masks)"))

    ng, s = MS_DB["groups"], MS_DB["group_size"]
    gm = _sds((ng, s, cfg.height, cfg.width), jnp.float32, row4)
    grois = _sds((ng, 4), jnp.int32, row2)
    cells.append(Cell("masksearch", "iou_agg_256k", "query",
                      dist.make_iou_agg_step(mesh), (gm, grois, lv), 1, 0.0,
                      note="fused MASK_AGG IoU over 262k image groups"))
    return cells
