"""Serving driver: prefill + batched greedy decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch granite_3_2b --smoke \
        --batch 4 --prompt-len 16 --gen 32

Demonstrates the serve_step path the decode_* dry-run cells lower: the cache
layout, position bookkeeping, and (on a real mesh) seq-sharded KV.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, load_arch, load_smoke
from ..models import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = load_smoke(args.arch) if args.smoke else load_arch(args.arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    max_len = args.prompt_len + args.gen
    if cfg.is_encoder_decoder:
        batch = {
            "audio_feats": rng.standard_normal(
                (args.batch, 64, cfg.d_model)).astype(np.float32),
            "tokens": rng.integers(0, cfg.vocab_size,
                                   (args.batch, args.prompt_len)).astype(np.int32),
        }
        cache = model.init_cache(args.batch, enc_len=64)
    else:
        batch = {"tokens": rng.integers(
            0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)}
        if cfg.num_patches:
            batch["patches"] = rng.standard_normal(
                (args.batch, cfg.num_patches, cfg.d_model)).astype(np.float32)
        cache = model.init_cache(args.batch, max_len)

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill * 1e3:.1f} ms")

    pos0 = args.prompt_len + (cfg.num_patches or 0)
    out_tokens = [np.asarray(token)]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, token, jnp.int32(pos0 + i))
        token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(token))
    jax.block_until_ready(token)
    dt = time.time() - t0
    toks = np.concatenate(out_tokens, axis=1)
    print(f"decoded {args.gen - 1} steps x{args.batch} in {dt * 1e3:.1f} ms "
          f"({(args.gen - 1) * args.batch / max(dt, 1e-9):.0f} tok/s)")
    print("sample:", toks[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
