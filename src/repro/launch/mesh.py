"""Production meshes (assignment-mandated geometry).

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model").

Defined as functions so importing this module never touches jax device
state — the dry-run must set XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax

from ..core.distributed import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(shape=None, axes=None):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (n,), ("data",)
    return make_mesh(shape, axes)


# TPU v5e constants used by the roofline analysis (assignment-provided).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
