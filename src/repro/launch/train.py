"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b --smoke \
        --steps 200 --seq-len 64 --global-batch 8 --ckpt-dir /tmp/run1

Production shape (documented; same code path):
  * mesh from ``make_production_mesh()`` when >1 device is present,
    activation rules installed, params/optimizer sharded from logical axes;
  * checkpoint every ``--save-every`` steps, atomic, resumable (restart the
    same command — it resumes from the latest committed step, elastic across
    device counts);
  * SIGTERM → checkpoint-and-exit (preemption guard);
  * optional mask harvesting into a MaskSearch store every
    ``--harvest-every`` steps (the workflow integration).
"""

from __future__ import annotations

import argparse
import time

import jax

from ..configs import ARCH_IDS, load_arch, load_smoke
from ..data.pipeline import SyntheticLMData
from ..models import build_model
from ..train import checkpoint as ckpt
from ..train.fault import PreemptionGuard
from ..train.optimizer import OptConfig
from ..train.train_loop import init_train_state, make_train_step
from . import sharding as sh
from .mesh import make_local_mesh, make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    cfg = load_smoke(args.arch) if args.smoke else load_arch(args.arch)
    model = build_model(cfg)
    opt_cfg = OptConfig(learning_rate=args.lr, warmup_steps=args.steps // 10,
                        total_steps=args.steps)

    n_dev = len(jax.devices())
    mesh = None
    pshard = None
    if args.production_mesh:
        mesh = make_production_mesh()
    elif n_dev > 1:
        mesh = make_local_mesh()
    params, axes, opt_state = init_train_state(
        model, jax.random.PRNGKey(0), opt_cfg)
    if mesh is not None:
        sh.install_activation_rules(mesh)
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        pshard = sh.param_sharding_tree(mesh, shapes, axes)
        params = jax.tree.map(jax.device_put, params, pshard)

    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatches=args.microbatches,
                                      param_shardings=pshard))
    data = SyntheticLMData(cfg, args.seq_len, args.global_batch)
    guard = PreemptionGuard()

    start = 0
    if args.ckpt_dir:
        state, at = ckpt.restore_latest(
            args.ckpt_dir, {"params": params, "opt": opt_state})
        if state is not None:
            params, opt_state = state["params"], state["opt"]
            start = at + 1
            print(f"resumed from step {at}")

    t0 = time.time()
    for s in range(start, args.steps):
        params, opt_state, metrics = step_fn(params, opt_state,
                                             data.batch_at(s))
        if s % args.log_every == 0 or s == args.steps - 1:
            loss = float(metrics["loss"])
            rate = (s - start + 1) / (time.time() - t0)
            print(f"step {s:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e} [{rate:.2f} it/s]",
                  flush=True)
        stop = guard.should_stop
        if args.ckpt_dir and (stop or (s and s % args.save_every == 0)
                              or s == args.steps - 1):
            ckpt.save(args.ckpt_dir, s, {"params": params, "opt": opt_state})
        if stop:
            print(f"preempted — checkpointed at step {s}, exiting cleanly")
            return 0
    print("done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
