import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (assignment deliverable e).

For every (architecture × input shape) cell and both production meshes this
lowers + compiles the real step function with ShapeDtypeStruct inputs and
the production shardings, then records:

  * compile success (the gate),
  * ``memory_analysis()``   — bytes/device: does it fit 16 GB HBM,
  * ``cost_analysis()``     — per-device FLOPs/bytes,
  * the collective schedule — kinds/counts/bytes parsed from the HLO,
  * roofline terms          — via the 1-group/2-group linearization
                              (single-pod only; see roofline/extract.py).

Usage:
    python -m repro.launch.dryrun --arch granite_3_2b --shape train_4k
    python -m repro.launch.dryrun --all --mesh single
    python -m repro.launch.dryrun --all --mesh multi --no-cost
    python -m repro.launch.dryrun --masksearch --mesh single

Results are cached as JSON under launch's ``--out`` dir (default
``dryrun_results/``); re-runs skip completed cells unless ``--force``.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from ..configs.base import ARCH_IDS, SHAPES, load_arch
from ..roofline.extract import CellCost, Roofline
from . import sharding as sh
from .mesh import make_production_mesh
from .specs import build_cell, build_masksearch_cells


def _reduced_cfg(cfg, groups: int):
    """Config with the layer stack cut to `groups` UNROLLED groups (same
    prefix/tail structure), microbatching off — the cost-linearization
    variants.  Unrolling matters: cost_analysis counts a scanned while body
    once regardless of trip count (verified; EXPERIMENTS.md §Roofline)."""
    if cfg.is_encoder_decoder:
        return dataclasses.replace(cfg, enc_layers=groups, dec_layers=groups,
                                   num_layers=groups,
                                   microbatches_train_4k=1,
                                   unroll_groups=True)
    glen = len(cfg.layer_pattern)
    prefix = cfg.first_k_dense if cfg.num_experts else 0
    tail = len(cfg.tail_layers)
    return dataclasses.replace(
        cfg, num_layers=prefix + groups * glen + tail,
        microbatches_train_4k=1, unroll_groups=True)


def _mem_dict(mem) -> dict:
    return {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_estimate_bytes": (mem.argument_size_in_bytes +
                                mem.temp_size_in_bytes +
                                mem.output_size_in_bytes -
                                mem.alias_size_in_bytes),
    }


def compile_cell(cell):
    t0 = time.time()
    lowered = cell.step_fn.lower(*cell.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def run_cell(arch: str, shape_id: str, mesh_kind: str, *, with_cost: bool,
             out_dir: str, force: bool = False,
             cost_only: bool = False) -> dict:
    path = os.path.join(out_dir, mesh_kind, f"{arch}__{shape_id}.json")
    record = {"arch": arch, "shape": shape_id, "mesh": mesh_kind}
    if os.path.exists(path):
        with open(path) as f:
            existing = json.load(f)
        if not force and not cost_only:
            return existing
        if cost_only:
            record = existing            # refresh only the 1g/2g linearization
    os.makedirs(os.path.dirname(path), exist_ok=True)

    cfg = load_arch(arch)
    ok, reason = cfg.supports_shape(shape_id)
    if not ok:
        record.update(status="skipped", reason=reason)
        _write(path, record)
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    sh.install_activation_rules(mesh, cfg)
    if cost_only and record.get("status") == "ok":
        try:
            cell = build_cell(arch, cfg, shape_id, mesh)
            costs = []
            for g in (1, 2):
                rcfg = _reduced_cfg(cfg, g)
                rcell = build_cell(arch, rcfg, shape_id, mesh)
                rcomp, _, _ = compile_cell(rcell)
                costs.append(CellCost.from_compiled(rcomp))
            lin = costs[0].linearize(costs[1], cell.n_groups)
            roof = Roofline.from_cost(lin, n_chips, cell.model_flops)
            record.update(linearized_cost=dataclasses.asdict(lin),
                          roofline=roof.to_dict(), n_groups=cell.n_groups)
        except Exception as e:
            record.update(roofline_error=f"{type(e).__name__}: {e}")
        finally:
            sh.clear_activation_rules()
        _write(path, record)
        return record
    try:
        cell = build_cell(arch, cfg, shape_id, mesh)
        compiled, t_lower, t_compile = compile_cell(cell)
        mem = compiled.memory_analysis()
        cost_full = CellCost.from_compiled(compiled)
        record.update(
            status="ok",
            kind=cell.kind,
            n_chips=n_chips,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=_mem_dict(mem),
            fits_16g=bool(_mem_dict(mem)["peak_estimate_bytes"] < 16e9),
            low_mem_opt=cell.low_mem_opt,
            scanned_cost=dataclasses.asdict(cost_full),
            model_flops=cell.model_flops,
        )
        if with_cost:
            # 1-group / 2-group unrolled compiles → linearized roofline
            costs = []
            for g in (1, 2):
                rcfg = _reduced_cfg(cfg, g)
                rcell = build_cell(arch, rcfg, shape_id, mesh)
                rcomp, _, _ = compile_cell(rcell)
                costs.append(CellCost.from_compiled(rcomp))
            lin = costs[0].linearize(costs[1], cell.n_groups)
            roof = Roofline.from_cost(lin, n_chips, cell.model_flops)
            record.update(
                linearized_cost=dataclasses.asdict(lin),
                roofline=roof.to_dict(),
                n_groups=cell.n_groups,
            )
    except Exception as e:  # a failing cell is a bug — record it loudly
        record.update(status="failed", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
    finally:
        sh.clear_activation_rules()
    _write(path, record)
    return record


def run_masksearch(mesh_kind: str, out_dir: str, force: bool = False):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    results = []
    for cell in build_masksearch_cells(mesh):
        path = os.path.join(out_dir, mesh_kind,
                            f"masksearch__{cell.shape_id}.json")
        if os.path.exists(path) and not force:
            with open(path) as f:
                results.append(json.load(f))
            continue
        os.makedirs(os.path.dirname(path), exist_ok=True)
        record = {"arch": "masksearch", "shape": cell.shape_id,
                  "mesh": mesh_kind, "note": cell.note}
        try:
            compiled, t_lower, t_compile = compile_cell(cell)
            mem = compiled.memory_analysis()
            cost = CellCost.from_compiled(compiled)
            roof = Roofline.from_cost(cost, n_chips, 0.0)
            record.update(status="ok", n_chips=n_chips,
                          lower_s=round(t_lower, 1),
                          compile_s=round(t_compile, 1),
                          memory=_mem_dict(mem),
                          cost=dataclasses.asdict(cost),
                          roofline=roof.to_dict())
        except Exception as e:
            record.update(status="failed", error=f"{type(e).__name__}: {e}",
                          traceback=traceback.format_exc()[-4000:])
        _write(path, record)
        results.append(record)
    return results


def _write(path: str, record: dict):
    with open(path + ".tmp", "w") as f:
        json.dump(record, f, indent=1, default=float)
    os.replace(path + ".tmp", path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--masksearch", action="store_true")
    ap.add_argument("--no-cost", action="store_true",
                    help="skip the 1g/2g roofline compiles")
    ap.add_argument("--cost-only", action="store_true",
                    help="refresh only the 1g/2g linearization of cached cells")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="dryrun_results")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "dry-run needs the 512 fake devices"
    with_cost = not args.no_cost and args.mesh == "single"

    if args.masksearch:
        for r in run_masksearch(args.mesh, args.out, args.force):
            _report(r)
        return

    cells = ([(args.arch, args.shape)] if args.arch and args.shape else
             [(a, s) for a in ARCH_IDS for s in SHAPES] if args.all else None)
    if cells is None:
        raise SystemExit("pass --arch+--shape, --all, or --masksearch")
    for arch, shape in cells:
        r = run_cell(arch, shape, args.mesh, with_cost=with_cost,
                     out_dir=args.out, force=args.force,
                     cost_only=args.cost_only)
        _report(r)


def _report(r: dict):
    status = r.get("status")
    if status == "ok":
        mem = r.get("memory", {})
        peak = mem.get("peak_estimate_bytes", 0) / 1e9
        roof = r.get("roofline") or {}
        print(f"[OK]   {r['arch']:22s} {r['shape']:16s} {r['mesh']:6s} "
              f"peak={peak:7.2f}GB/dev "
              f"dominant={roof.get('dominant', '-'):10s} "
              f"compile={r.get('compile_s', 0):6.1f}s", flush=True)
    elif status == "skipped":
        print(f"[SKIP] {r['arch']:22s} {r['shape']:16s} {r['mesh']:6s} "
              f"{r.get('reason', '')}", flush=True)
    else:
        print(f"[FAIL] {r['arch']:22s} {r['shape']:16s} {r['mesh']:6s} "
              f"{r.get('error', '')[:160]}", flush=True)


if __name__ == "__main__":
    main()
