"""Logical-axis → mesh-axis mapping (the GSPMD plan for every arch).

Models annotate parameters and activations with *logical* names
(models/layers.py); this module turns them into ``PartitionSpec``s for
whatever mesh is active, with two safety rules:

  * **divisibility** — a mesh axis is only used if it divides the dimension
    (GQA kv=8 on a 16-way "model" axis falls back to replication, matching
    practice);
  * **single-use** — a mesh axis appears at most once per spec (e.g. the
    RG-LRU (w, w) square matrices shard only one side).

The plan (DESIGN.md §6):
  params   — FSDP ("embed" over data×pod, ZeRO-3) × TP ("model" on
             heads/mlp/vocab) × EP (experts over "model");
  acts     — batch over data×pod, heads/mlp/vocab over "model";
  caches   — decode KV **sequence** over "model" (flash-decoding SP);
             SSM/RG-LRU states shard heads/width over "model".
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import layers as L

# Candidate mesh axes per logical axis, in priority order.  Tuples are used
# jointly (FSDP over data AND pod); the resolver drops members that are
# absent, already used, or non-divisible.
PARAM_RULES: dict[str, tuple] = {
    "vocab": ("model",),
    "embed": ("data", "pod"),          # ZeRO-3 / FSDP
    "q_heads": ("model",),
    "kv_heads": ("model",),
    "heads": ("model",),
    "mlp": ("model",),
    "expert_mlp": (),                  # experts already take "model"
    "experts": ("model",),
    "q_lora": (), "kv_lora": (), "head_dim": (), "conv": (),
    "state": (), "mlp2": (), "layers": (),
}

ACT_RULES: dict[str, tuple] = {
    "batch": ("pod", "data"),
    "tokens": ("pod", "data"),         # flattened (B·S) MoE dispatch rows
    "seq": (),
    "embed": (),
    "mlp": ("model",),
    "expert_mlp": (),
    "experts": ("model",),
    "heads": ("model",),
    "q_heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "kv_seq": ("model",),              # seq-parallel cross/decode KV
}

# Pure-DP variant (small dense models, §Perf iter 8): batch over the whole
# mesh, no tensor parallelism; vocab keeps "model" (free in fwd, one small
# AR in bwd) so the logits never replicate.
PURE_DP_PARAM_RULES = dict(PARAM_RULES, **{
    "q_heads": (), "kv_heads": (), "heads": (), "mlp": (), "experts": (),
    "embed": ("data",),                # ZeRO over data only
})
PURE_DP_ACT_RULES = dict(ACT_RULES, **{
    "batch": ("pod", "data", "model"),
    "tokens": ("pod", "data", "model"),
    "mlp": (), "heads": (), "q_heads": (), "kv_heads": (), "experts": (),
})


def rules_for(cfg=None, mesh=None):
    """(param_rules, act_rules) for a config (pure-DP override aware).

    Pure DP only pays when the global batch covers the whole mesh (train_4k
    batch 256 == the 256-chip single pod); on the 512-chip multi-pod mesh
    the same batch cannot, so those cells keep the TP mapping."""
    if cfg is not None and getattr(cfg, "prefer_pure_dp", False):
        if mesh is None or "pod" not in mesh.axis_names:
            return PURE_DP_PARAM_RULES, PURE_DP_ACT_RULES
    return PARAM_RULES, ACT_RULES


def _resolve_dim(mesh: Mesh, cand: tuple, size: int, used: set):
    """Pick the largest usable prefix of candidate axes for one dimension."""
    picked = []
    prod = 1
    for ax in cand:
        if ax not in mesh.axis_names or ax in used:
            continue
        n = mesh.shape[ax]
        if size % (prod * n) == 0:
            picked.append(ax)
            prod *= n
    for ax in picked:
        used.add(ax)
    if not picked:
        return None
    return tuple(picked) if len(picked) > 1 else picked[0]


def spec_for(mesh: Mesh, rules: dict, axes: tuple, shape: tuple) -> P:
    used: set = set()
    out = []
    for name, size in zip(axes, shape):
        if name is None:
            out.append(None)
            continue
        cand = rules.get(name, ())
        out.append(_resolve_dim(mesh, cand, int(size), used))
    return P(*out)


def param_sharding_tree(mesh: Mesh, shapes: Any, axes: Any, cfg=None):
    """shapes: pytree of ShapeDtypeStruct (from eval_shape); axes: logical
    axes pytree.  → same-structure tree of NamedSharding."""
    rules = rules_for(cfg, mesh)[0]
    is_tup = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    return jax.tree.map(
        lambda s, a: NamedSharding(mesh, spec_for(mesh, rules, a, s.shape)),
        shapes, axes, is_leaf=lambda x: x is None or is_tup(x))


def install_activation_rules(mesh: Mesh, cfg=None) -> None:
    """Hook models' shard_act onto this mesh (launcher entry point)."""
    rules = rules_for(cfg, mesh)[1]

    def rule(x, axes):
        spec = spec_for(mesh, rules, axes, x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    L.set_activation_rule(rule)


def clear_activation_rules() -> None:
    L.set_activation_rule(None)


# -- cache shardings (decode / prefill) --------------------------------------

_CACHE_LEAF_AXES = {
    # leaf-name → logical axes by rank
    "k": ("batch", "kv_seq", "kv_heads_repl", None),
    "v": ("batch", "kv_seq", "kv_heads_repl", None),
    "xk": ("batch", "kv_seq", "kv_heads_repl", None),
    "xv": ("batch", "kv_seq", "kv_heads_repl", None),
    "ckv": ("batch", "kv_seq", None),
    "kpe": ("batch", "kv_seq", None),
    "state": ("batch", "heads", None, None),
    "conv": ("batch", None, "mlp"),
    "h": ("batch", "mlp"),
}

_CACHE_RULES = dict(ACT_RULES)
_CACHE_RULES["kv_heads_repl"] = ()     # seq takes "model"; heads replicate


def cache_sharding_tree(mesh: Mesh, cache_shapes: Any):
    """Assign shardings to a cache pytree (by leaf name, via tree paths).
    Stacked group caches get their leading layer axis replicated."""

    def assign(path, leaf):
        name = None
        for entry in reversed(path):
            key = getattr(entry, "key", None)
            if isinstance(key, str):
                name = key
                break
        axes = _CACHE_LEAF_AXES.get(name)
        if axes is None:
            return NamedSharding(mesh, P())
        rank = len(leaf.shape)
        if rank == len(axes) + 1:      # stacked over scan groups
            axes = (None,) + axes
        axes = axes[:rank] if len(axes) >= rank else axes + (None,) * (
            rank - len(axes))
        return NamedSharding(mesh, spec_for(mesh, _CACHE_RULES, axes,
                                            leaf.shape))

    return jax.tree_util.tree_map_with_path(assign, cache_shapes)


def batch_sharding_tree(mesh: Mesh, batch_shapes: Any, cfg=None):
    """Token/label/feature batches: shard axis 0 (batch) over data axes."""
    rules = rules_for(cfg, mesh)[1]

    def assign(leaf):
        axes = ("batch",) + (None,) * (len(leaf.shape) - 1)
        return NamedSharding(mesh, spec_for(mesh, rules, axes, leaf.shape))
    return jax.tree.map(assign, batch_shapes)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
