"""Sharded, atomic, resumable checkpointing (no orbax dependency).

Layout::

    <dir>/step_000123.tmp/         ← written first
        manifest.json              (step, rng, tree structure, leaf shapes)
        leaf_00000.npy …           (one file per pytree leaf; on multi-host
                                    each host writes its addressable shards)
    <dir>/step_000123/             ← atomic rename marks the commit
    <dir>/LATEST                   ← text file, updated after the rename

Fault-tolerance contract (tested in tests/test_checkpoint.py):
  * a crash mid-write leaves only a ``.tmp`` dir → ignored on restore;
  * ``restore_latest`` returns the newest *committed* step;
  * ``keep`` bounds disk usage (old committed steps pruned after commit);
  * restore accepts a target sharding tree — arrays are re-sharded on load,
    which is what makes **elastic restarts** (different device count) work.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree) -> list:
    leaves, _ = jax.tree.flatten(tree)
    return leaves


def save(ckpt_dir: str, step: int, state: Any, *, keep: int = 3) -> str:
    """Write one committed checkpoint; returns its path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = jax.tree.flatten(state)
    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "treedef": str(treedef),
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.name == "bfloat16":       # numpy can't round-trip bf16
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        manifest["leaves"].append({
            "shape": list(arr.shape),
            "dtype": logical_dtype,
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)            # the commit point
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(name)
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))

    # prune old committed steps
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for old in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, old), ignore_errors=True)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        # LATEST points at a pruned/corrupt dir → fall back to newest on disk
        steps = sorted(d for d in os.listdir(ckpt_dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        if not steps:
            return None
        name = steps[-1]
    return int(name.split("_")[1])


def restore(ckpt_dir: str, step: int, like: Any, *,
            shardings: Any = None) -> Any:
    """Load a committed step into the structure of ``like``.

    ``shardings``: optional same-structure tree of NamedShardings — arrays
    are placed onto them (elastic re-shard happens here: the on-disk arrays
    are full-size and get re-split for whatever mesh is now active).
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(like)
    if manifest["num_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['num_leaves']} leaves, expected "
            f"{len(leaves)} — structure mismatch")
    out = []
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves))
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        if manifest["leaves"][i]["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {ref.shape}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return jax.tree.unflatten(treedef, out)


def restore_latest(ckpt_dir: str, like: Any, *, shardings: Any = None):
    """→ (state, step) or (None, -1) when no committed checkpoint exists."""
    step = latest_step(ckpt_dir)
    if step is None:
        return None, -1
    return restore(ckpt_dir, step, like, shardings=shardings), step
