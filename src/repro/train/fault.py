"""Fault tolerance: preemption handling, elastic restarts, stragglers.

What runs here (testable on CPU):
  * :class:`PreemptionGuard` — SIGTERM/SIGINT → finish the in-flight step,
    checkpoint, exit cleanly.  The training loop polls ``should_stop``.
  * :func:`elastic_restore` — restore a checkpoint onto a *different* mesh
    than the one it was saved from (full-size arrays on disk re-shard onto
    whatever mesh is active; tested 1→8→1 devices in
    tests/test_checkpoint.py).

What is configured here and documented for real clusters (DESIGN.md §6):
  * **straggler mitigation** — synchronous SPMD makes one slow host drag the
    step.  Mitigations wired into this codebase: (a) bounded host-side data
    prefetch (data/pipeline.py) so input hiccups don't stall the collective;
    (b) checkpoint cadence + preemption guard so evicting a straggling node
    costs at most ``save_every`` steps; (c) the launcher's
    ``--coordinator_timeout`` maps to jax.distributed initialize timeouts.
  * **elastic scaling** — on restart with a different pod count the same
    checkpoint restores because checkpoints are device-layout-free
    (full arrays + re-shard on load).  Batch-size schedules across
    re-scales are the caller's policy.
"""

from __future__ import annotations

import signal
import threading

from . import checkpoint as ckpt_lib


class PreemptionGuard:
    """Install signal handlers; training loops poll ``should_stop``."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._stop = threading.Event()
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:  # non-main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._stop.set()

    @property
    def should_stop(self) -> bool:
        return self._stop.is_set()

    def trigger(self) -> None:  # test hook: simulate a preemption
        self._stop.set()

    def restore_handlers(self) -> None:
        for s, h in self._prev.items():
            signal.signal(s, h)


def elastic_restore(ckpt_dir: str, like, shardings=None):
    """Restore the latest committed step onto the current mesh (which may
    have a different device count than the mesh that saved it)."""
    return ckpt_lib.restore_latest(ckpt_dir, like, shardings=shardings)
