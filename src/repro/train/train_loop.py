"""Train-step factory: microbatch gradient accumulation + remat + AdamW.

``make_train_step(model, opt_cfg, microbatches)`` returns a jit-ready

    train_step(params, opt_state, batch) → (params, opt_state, metrics)

With ``microbatches > 1`` the global batch splits along axis 0 and a
``lax.scan`` accumulates grads (fp32) — per-step activation memory drops by
the microbatch factor while param/optimizer memory is untouched; this is
what lets the 200B+ MoE cells fit their activations (DESIGN.md §6).  The
model's own remat policy handles the within-layer recompute.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .optimizer import OptConfig, apply_updates, init_opt_state


def _split_batch(batch, n: int):
    def r(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by {n} microbatches"
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(r, batch)


def make_loss_and_grads(model, microbatches: int = 1, param_shardings=None):
    """(params, batch) → (loss, metrics, grads).

    ``param_shardings``: optional pytree of NamedShardings matching params.
    Cotangents do NOT reliably inherit the primal's sharding through
    value_and_grad + scan — without pinning, grads of TP/EP-sharded weights
    come back replicated over "model" (measured 84 GB/device on
    deepseek-v3; EXPERIMENTS.md §Perf).  We constrain grads and the f32
    accumulator to shard exactly like their parameters.
    """

    def pin(tree):
        if param_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            param_shardings)

    def single(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        return loss, metrics, pin(grads)

    if microbatches == 1:
        return single

    def accumulated(params, batch):
        micro = _split_batch(batch, microbatches)
        g0 = pin(jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                              params))

        def body(carry, mb):
            gsum, lsum = carry
            loss, metrics, grads = single(params, mb)
            gsum = pin(jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / microbatches,
                gsum, grads))
            return (gsum, lsum + loss / microbatches), metrics

        (grads, loss), metrics = jax.lax.scan(
            body, (g0, jnp.zeros((), jnp.float32)), micro)
        metrics = jax.tree.map(lambda m: m.mean(), metrics)
        return loss, metrics, grads

    return accumulated


def make_train_step(model, opt_cfg: OptConfig, *, microbatches: int = 1,
                    donate: bool = True, param_shardings=None):
    loss_and_grads = make_loss_and_grads(model, microbatches, param_shardings)

    def train_step(params, opt_state, batch):
        loss, metrics, grads = loss_and_grads(params, batch)
        params, opt_state, opt_metrics = apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def init_train_state(model, rng, opt_cfg: OptConfig | None = None):
    """→ (params, axes, opt_state)."""
    opt_cfg = OptConfig() if opt_cfg is None else opt_cfg
    params, axes = model.init(rng)
    return params, axes, init_opt_state(params, opt_cfg)
