"""AdamW with global-norm clipping and cosine schedule — pure JAX.

Mixed-precision policy (DESIGN.md §6): model params live in bf16; the
optimizer keeps fp32 first/second moments **and an fp32 master copy** of the
params.  update() consumes bf16 grads, updates fp32 state, and emits fresh
bf16 params — the standard large-scale recipe.  All optimizer state shards
exactly like its parameter (ZeRO-style; the launcher assigns shardings from
the same logical axes).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # Memory policy.  Default: fp32 moments + fp32 master weights
    # (14 B/param with bf16 params).  Low-mem mode for the ≥200B MoE cells:
    # bf16 moments, no master (6 B/param) — production would use 8-bit
    # moments instead; the roofline table records which mode each cell used.
    moments_dtype: str = "float32"
    use_master: bool = True


class OptState(NamedTuple):
    step: jax.Array            # () int32
    mu: Any                    # moments_dtype, like params
    nu: Any                    # moments_dtype, like params
    master: Any                # fp32 master weights (or () in low-mem mode)


def init_opt_state(params, cfg: OptConfig | None = None) -> OptState:
    cfg = OptConfig() if cfg is None else cfg
    mdt = jnp.dtype(cfg.moments_dtype)
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, mdt), params)
    master = (jax.tree.map(lambda x: x.astype(jnp.float32), params)
              if cfg.use_master else ())
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros), master)


def lr_at(cfg: OptConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.learning_rate * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(cfg: OptConfig, params, grads, state: OptState):
    """→ (new_params (bf16-like params), new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    lr = lr_at(cfg, step)

    mdt = jnp.dtype(cfg.moments_dtype)

    def upd(g, mu, nu, ref):
        """ref: fp32 master (master mode) or the bf16 param (low-mem)."""
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32)
        nu32 = nu.astype(jnp.float32)
        mu32 = b1 * mu32 + (1 - b1) * g
        nu32 = b2 * nu32 + (1 - b2) * jnp.square(g)
        update = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + cfg.eps)
        w = ref.astype(jnp.float32)
        w = w - lr * (update + cfg.weight_decay * w)
        return mu32.astype(mdt), nu32.astype(mdt), w

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    flat_ref = (treedef.flatten_up_to(state.master) if cfg.use_master
                else flat_p)
    out = [upd(g, m, n, w) for g, m, n, w in
           zip(flat_g, flat_mu, flat_nu, flat_ref)]
    mu = jax.tree.unflatten(treedef, [o[0] for o in out])
    nu = jax.tree.unflatten(treedef, [o[1] for o in out])
    master = (jax.tree.unflatten(treedef, [o[2] for o in out])
              if cfg.use_master else ())

    new_params = jax.tree.unflatten(
        treedef, [o[2].astype(p.dtype) for o, p in zip(out, flat_p)])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, mu, nu, master), metrics
