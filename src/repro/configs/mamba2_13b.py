"""Mamba2-1.3B (SSD, state-space duality) [arXiv:2405.21060; unverified].

48L attention-free SSM: d_model=2048, d_inner=4096 (expand 2),
64 SSD heads × head_dim 64, state=128, conv width 4, chunk 256,
vocab=50280.  Owns the long_500k cell (O(1)-state decode).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    layer_pattern=("ssm",),
    attention="none",
    ssm_state=128,
    ssm_heads=64,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    chunk_size=256,
    tie_embeddings=True,
    microbatches_train_4k=1,
    prefer_pure_dp=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    num_layers=4,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=256,
    layer_pattern=("ssm",),
    attention="none",
    ssm_state=16,
    ssm_heads=4,
    ssm_head_dim=32,
    ssm_expand=2,
    conv_width=4,
    chunk_size=32,
    tie_embeddings=True,
    remat=False,
)
