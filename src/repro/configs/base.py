"""ModelConfig — one dataclass drives every architecture in the zoo.

Each assigned architecture gets a module in this package defining
``CONFIG`` (the exact published geometry) and ``SMOKE`` (a reduced config of
the same family for CPU tests).  ``registry()`` maps ``--arch`` ids to them.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

ARCH_IDS = (
    "deepseek_v3_671b",
    "deepseek_v2_236b",
    "granite_3_2b",
    "codeqwen15_7b",
    "qwen3_32b",
    "gemma3_27b",
    "recurrentgemma_2b",
    "internvl2_1b",
    "mamba2_13b",
    "whisper_large_v3",
)

# Input-shape suite shared by every LM arch (assignment table).
SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # layer stacking: the repeating unit (scanned); kinds:
    #   "global" (full attn) | "local" (sliding window) | "rglru" | "ssm"
    layer_pattern: tuple = ("global",)

    # attention flavor
    attention: str = "gqa"           # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_theta_local: Optional[float] = None
    local_window: int = 0

    # MLA (DeepSeek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001

    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256

    # RG-LRU
    lru_width: int = 0

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    dec_layers: int = 0
    max_decode_len: int = 448

    # VLM stub frontend
    num_patches: int = 0

    # multi-token prediction (DeepSeek-V3)
    mtp_depth: int = 0
    mtp_weight: float = 0.3

    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    pos_embedding: str = "rope"      # rope | absolute (whisper)
    embed_scale: float = 1.0         # gemma: sqrt(d_model)
    # memory-efficient attention: query-block size (0 = unblocked).  Blocks
    # are unrolled (not scanned) so cost_analysis counts their FLOPs.
    attn_q_block: int = 1024
    # Unroll the layer-group scan (cost-measurement variants only: XLA's
    # cost_analysis counts a while body once, so the roofline 1g/2g compiles
    # must not scan).  Production configs keep the scan for compile time.
    unroll_groups: bool = False
    # Pure-DP mapping for small models (§Perf iter 8): batch shards over the
    # WHOLE mesh (incl. "model"), params replicate over "model" (vocab dim
    # excepted) — per-layer TP all-reduces vanish; the only large collective
    # left is the per-step grad reduction.  Right when bf16 params fit one
    # chip comfortably (≤ ~3B params).
    prefer_pure_dp: bool = False

    # training-time knobs (used by launch/, not by model math)
    remat: bool = True
    microbatches_train_4k: int = 1
    logit_softcap: float = 0.0

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a 128 multiple when it doesn't already divide
        the 16-way model axis — unlocks vocab sharding of embeddings and
        logits (a ~20 GB/device lever at 4k×256; see EXPERIMENTS.md §Perf).
        Pad logit columns are masked to −inf in the LM head."""
        if self.vocab_size % 16 == 0:
            return self.vocab_size
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM/hybrid — O(1)-state decode)."""
        return self.family in ("ssm", "hybrid")

    @property
    def pattern_layers(self) -> tuple:
        """Per-layer kinds for the full stack: pattern repeated + truncated."""
        reps = -(-self.num_layers // len(self.layer_pattern))
        return (self.layer_pattern * reps)[: self.num_layers]

    @property
    def num_groups(self) -> int:
        """Whole repetitions of the pattern (the scanned trip count)."""
        return self.num_layers // len(self.layer_pattern)

    @property
    def tail_layers(self) -> tuple:
        """Layers past the last whole group (unrolled separately)."""
        return self.pattern_layers[self.num_groups * len(self.layer_pattern):]

    def supports_shape(self, shape: str) -> tuple[bool, str]:
        """(runnable, reason-if-skipped) for an assignment shape id."""
        if shape == "long_500k" and not self.sub_quadratic:
            return False, ("full-attention family: 500k-token decode needs "
                           "sub-quadratic attention (DESIGN.md §7)")
        return True, ""


def load_arch(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def load_smoke(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.SMOKE


def registry() -> dict:
    return {a: load_arch(a) for a in ARCH_IDS}
