"""InternVL2-1B [arXiv:2404.16821; hf].

VLM: InternViT frontend (STUB — input_specs provides precomputed patch
embeddings) + Qwen2-0.5B-class LM backbone: 24L, d_model=896, 14 heads
(GQA kv=2, head_dim=64), d_ff=4864, vocab=151655, tied embeddings.
256 patch embeddings are prepended to the text sequence.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    num_patches=256,
    tie_embeddings=True,
    rope_theta=1e6,
    microbatches_train_4k=2,
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    num_patches=8,
    tie_embeddings=True,
    remat=False,
)
