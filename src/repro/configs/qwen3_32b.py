"""Qwen3-32B [hf:Qwen/Qwen3-32B family].

64L dense, d_model=5120, 64 heads (GQA kv=8, head_dim=128), d_ff=25600,
vocab=151936, per-head qk-norm.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    microbatches_train_4k=8,
)

SMOKE = ModelConfig(
    name="qwen3-smoke",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=256,
    qk_norm=True,
    remat=False,
)
