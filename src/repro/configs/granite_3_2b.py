"""IBM Granite-3.0 2B base [hf:ibm-granite/granite-3.0-2b-base].

40L dense, d_model=2048, 32 heads (GQA kv=8, head_dim=64), d_ff=8192,
vocab=49155, tied embeddings.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49155,
    tie_embeddings=True,
    rope_theta=1e4,
    microbatches_train_4k=1,
    prefer_pure_dp=True,
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    tie_embeddings=True,
    remat=False,
)
