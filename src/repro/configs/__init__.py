"""Architecture configs: one module per assigned arch (+ smoke variants)."""

from .base import ARCH_IDS, SHAPES, ModelConfig, load_arch, load_smoke, registry  # noqa: F401
