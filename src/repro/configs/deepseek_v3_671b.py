"""DeepSeek-V3 671B [arXiv:2412.19437; hf].

61L, d_model=7168, 128 MLA heads, MoE 1 shared + 256 routed top-8
(expert d_ff=2048), first 3 layers dense (d_ff=18432), MTP depth 1,
vocab 129280.  MLA: q_lora=1536, kv_lora=512, rope=64, nope=128, v=128.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=192,                 # nope(128) + rope(64)
    d_ff=18432,                   # the 3 leading dense layers
    vocab_size=129280,
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    num_experts=256,
    num_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    first_k_dense=3,
    mtp_depth=1,
    rope_theta=1e4,
    microbatches_train_4k=8,
)

SMOKE = ModelConfig(
    name="deepseek-v3-smoke",
    family="moe",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=24,
    d_ff=128,
    vocab_size=256,
    attention="mla",
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_rope_dim=8,
    qk_nope_dim=16,
    v_head_dim=16,
    num_experts=8,
    num_shared_experts=1,
    top_k=2,
    moe_d_ff=32,
    first_k_dense=1,
    mtp_depth=1,
    remat=False,
)
