"""Whisper-large-v3 [arXiv:2212.04356; unverified tier].

Enc-dec: 32 encoder + 32 decoder layers, d_model=1280, 20 heads (MHA,
head_dim=64), d_ff=5120, vocab=51866, absolute sinusoidal positions,
decoder context 448.  Conv frontend is a STUB — input_specs provides
precomputed frame embeddings; the 32k/500k shape lengths live in the
cross-attention KV (encoder frames).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,                 # per assignment: 32L backbone
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    is_encoder_decoder=True,
    enc_layers=32,
    dec_layers=32,
    max_decode_len=448,
    pos_embedding="absolute",
    microbatches_train_4k=4,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    is_encoder_decoder=True,
    enc_layers=2,
    dec_layers=2,
    max_decode_len=32,
    pos_embedding="absolute",
    remat=False,
)
