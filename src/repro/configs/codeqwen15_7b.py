"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B].

32L dense (qwen1.5 arch), d_model=4096, 32 heads (kv=32 → MHA,
head_dim=128), d_ff=13440, vocab=92416.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    rope_theta=1e6,
    microbatches_train_4k=4,
)

SMOKE = ModelConfig(
    name="codeqwen-smoke",
    family="dense",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=160,
    vocab_size=256,
    remat=False,
)
