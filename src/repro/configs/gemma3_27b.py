"""Gemma-3 27B [hf:google/gemma-3-27b-pt; unverified tier].

62L dense with 5:1 local:global interleave (window 1024; local RoPE θ=1e4,
global θ=1e6), d_model=5376, 32 heads (GQA kv=16, head_dim=128),
d_ff=21504, vocab=262144, qk-norm, √d embedding scale.
62 = 10 whole (5L+1G) groups + 2 trailing local layers.
"""

import math

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    local_window=1024,
    rope_theta=1e6,
    rope_theta_local=1e4,
    qk_norm=True,
    embed_scale=math.sqrt(5376.0),
    tie_embeddings=True,
    microbatches_train_4k=8,
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    num_layers=8,                  # 1 whole group + 2 tail locals
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    local_window=16,
    rope_theta=1e6,
    rope_theta_local=1e4,
    qk_norm=True,
    embed_scale=8.0,
    tie_embeddings=True,
    remat=False,
)
