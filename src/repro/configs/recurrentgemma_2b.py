"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf].

26L hybrid, pattern (RG-LRU, RG-LRU, local-MQA) — 1:2 attention:recurrence.
d_model=2560, 10 heads (MQA kv=1, head_dim=256), d_ff=7680 (GeGLU-style
SwiGLU here), lru_width=2560, local window 2048, vocab=256000.
26 = 8 whole groups + 2 trailing RG-LRU layers.
"""

import math

from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    layer_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    lru_width=2560,
    conv_width=4,
    rope_theta=1e4,
    embed_scale=math.sqrt(2560.0),
    tie_embeddings=True,
    microbatches_train_4k=4,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    num_layers=5,                   # 1 group + 2 tail rglru
    d_model=64,
    num_heads=2,
    num_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab_size=256,
    layer_pattern=("rglru", "rglru", "local"),
    local_window=16,
    lru_width=64,
    conv_width=4,
    embed_scale=8.0,
    tie_embeddings=True,
    remat=False,
)
