"""DeepSeek-V2 236B [arXiv:2405.04434; hf].

60L, d_model=5120, 128 MLA heads (kv_lora=512), MoE 2 shared + 160 routed
top-6 (expert d_ff=1536), first layer dense (d_ff=12288), vocab 102400.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=192,
    d_ff=12288,                   # the leading dense layer
    vocab_size=102400,
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    num_experts=160,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    first_k_dense=1,
    rope_theta=1e4,
    microbatches_train_4k=8,
)

SMOKE = ModelConfig(
    name="deepseek-v2-smoke",
    family="moe",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=24,
    d_ff=128,
    vocab_size=256,
    attention="mla",
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_rope_dim=8,
    qk_nope_dim=16,
    v_head_dim=16,
    num_experts=8,
    num_shared_experts=2,
    top_k=2,
    moe_d_ff=32,
    first_k_dense=1,
    remat=False,
)
