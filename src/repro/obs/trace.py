"""Span tracing for the query engine (DESIGN.md §10).

Every traced query produces one **span tree** mirroring the
filter–verification pipeline: ``query`` → ``parse`` → ``plan.compile`` →
per-expression ``bounds`` spans (candidates, CHI bytes touched) →
``verify.round`` spans (masks, bytes, cache hits) — plus
``scheduler.fused_pass`` / ``scheduler.pair_pass`` when the service's
cross-query scheduler drives verification.  The span *structure* (names,
nesting, candidate/verified counts) is identical across the host, device,
and mesh backends because instrumentation lives in the backend-agnostic
drivers, never in the physical layers.

Design constraints:

* **Near-zero overhead when disabled.**  Instrumented code calls the
  module-level :func:`span`; with tracing off that is one contextvar read,
  one attribute check, and the shared no-op singleton — no Span object is
  ever allocated (``Tracer.spans_started`` stays 0, which the tests assert
  directly instead of timing).
* **Thread-safe, contextvar-scoped.**  The active tracer and the current
  parent span are both contextvars, so concurrent server threads build
  disjoint trees; the finished-trace ring buffer is lock-guarded.
* **Exportable.**  A finished trace renders as nested JSON
  (:meth:`Span.to_dict`) or as the Chrome trace-event format
  (:func:`chrome_trace` — load the JSON file in Perfetto / chrome://tracing).
"""

from __future__ import annotations

import contextlib
import contextvars
import itertools
import time
from collections import OrderedDict
from typing import Optional

from .. import lockcheck

__all__ = ["Span", "Tracer", "span", "current_tracer", "chrome_trace",
           "NOOP_SPAN", "GLOBAL_TRACER"]


def _jsonable(v):
    """Attrs may carry numpy scalars; normalize for json.dumps."""
    if isinstance(v, bool) or v is None or isinstance(v, (str, int, float)):
        return v
    if hasattr(v, "item"):
        return v.item()
    return repr(v)


class Span:
    """One timed node of a trace tree.  Use as a context manager; annotate
    with :meth:`set` (attrs merge; later wins)."""

    __slots__ = ("name", "t0", "dur_s", "attrs", "children",
                 "_tracer", "_token")

    def __init__(self, name: str, tracer: "Tracer"):
        self.name = name
        self.t0 = 0.0
        self.dur_s = 0.0
        self.attrs: dict = {}
        self.children: list = []
        self._tracer = tracer
        self._token = None

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    # -- context management ----------------------------------------------
    def __enter__(self) -> "Span":
        self.t0 = time.perf_counter()
        self._token = _CURRENT_SPAN.set(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.dur_s = time.perf_counter() - self.t0
        _CURRENT_SPAN.reset(self._token)
        self._token = None
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        if _CURRENT_SPAN.get() is None:
            # finished root: record into the owning tracer's ring buffer
            self._tracer._record(self)
        return False

    # -- export -----------------------------------------------------------
    def to_dict(self) -> dict:
        d: dict = {"name": self.name, "dur_s": self.dur_s}
        if self.attrs:
            d["attrs"] = {k: _jsonable(v) for k, v in self.attrs.items()}
        if self.children:
            d["children"] = [c.to_dict() for c in self.children]
        return d

    def walk(self):
        """Depth-first iteration over the subtree (self first)."""
        yield self
        for c in self.children:
            yield from c.walk()

    def structure(self) -> tuple:
        """The backend-invariant shape of the subtree: span names, nesting,
        and the count-valued attrs (times/bytes excluded — those may differ
        across physical backends; counts must not)."""
        counts = {k: _jsonable(v) for k, v in self.attrs.items()
                  if k in _STRUCTURAL_ATTRS}
        return (self.name, tuple(sorted(counts.items())),
                tuple(c.structure() for c in self.children))


#: Attr names that must be bit-identical across execution backends.
_STRUCTURAL_ATTRS = frozenset({
    "candidates", "decided_by_bounds", "verified", "batch", "rounds",
    "kind", "expr", "cached", "n_results",
})


class _NoopSpan:
    """Shared disabled-path singleton: every operation is a no-op and
    returns ``self``, so instrumented code never branches."""

    __slots__ = ()

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


NOOP_SPAN = _NoopSpan()

_CURRENT_SPAN: contextvars.ContextVar[Optional[Span]] = \
    contextvars.ContextVar("repro_obs_current_span", default=None)
_ACTIVE_TRACER: contextvars.ContextVar[Optional["Tracer"]] = \
    contextvars.ContextVar("repro_obs_active_tracer", default=None)


class Tracer:
    """Builds span trees and retains the most recent finished traces.

    One tracer per scope that wants retrievable traces (the service owns
    one; tests build their own).  ``enabled=False`` (the default for the
    global ambient tracer) short-circuits :func:`span` to the no-op
    singleton."""

    def __init__(self, enabled: bool = False, max_traces: int = 64):
        self.enabled = enabled
        self.max_traces = max_traces
        self.spans_started = 0           # the zero-allocation check counter
        self._traces: OrderedDict[str, Span] = OrderedDict()
        self._ids = itertools.count(1)
        self._lock = lockcheck.make_lock("obs.tracer")

    # -- span creation -----------------------------------------------------
    def span(self, name: str):
        """Start a child span of the current context (or a new root)."""
        if not self.enabled:
            return NOOP_SPAN
        with self._lock:
            self.spans_started += 1
        sp = Span(name, self)
        parent = _CURRENT_SPAN.get()
        if parent is not None:
            parent.children.append(sp)
        return sp

    def query_span(self, label: str = "", query_id: Optional[str] = None):
        """Start a root ``query`` span with an assigned ``query_id`` attr
        (available immediately, so callers can return it before the trace
        finishes).  Inside an existing trace it nests as an ordinary
        child span."""
        sp = self.span("query")
        if sp is NOOP_SPAN:
            return sp
        with self._lock:
            qid = query_id or f"q{next(self._ids)}"
        sp.set(query_id=qid)
        if label:
            sp.set(label=str(label)[:400])
        return sp

    @contextlib.contextmanager
    def activate(self):
        """Make this tracer the ambient one for the calling context (what
        the module-level :func:`span` resolves to)."""
        token = _ACTIVE_TRACER.set(self)
        try:
            yield self
        finally:
            _ACTIVE_TRACER.reset(token)

    # -- finished-trace retention -----------------------------------------
    def _record(self, root: Span) -> None:
        qid = root.attrs.get("query_id")
        if qid is None:
            with self._lock:
                qid = f"q{next(self._ids)}"
            root.attrs["query_id"] = qid
        with self._lock:
            self._traces[str(qid)] = root
            while len(self._traces) > self.max_traces:
                self._traces.popitem(last=False)

    def get_trace(self, query_id: str) -> Optional[Span]:
        with self._lock:
            return self._traces.get(str(query_id))

    def trace_ids(self) -> list:
        with self._lock:
            return list(self._traces)

    def last_trace(self) -> Optional[Span]:
        with self._lock:
            if not self._traces:
                return None
            return next(reversed(self._traces.values()))

    def clear(self) -> None:
        with self._lock:
            self._traces.clear()


GLOBAL_TRACER = Tracer(enabled=False)


def current_tracer() -> Tracer:
    """The ambient tracer: the innermost :meth:`Tracer.activate` scope, or
    the process-global (disabled-by-default) tracer."""
    return _ACTIVE_TRACER.get() or GLOBAL_TRACER


def span(name: str):
    """Start a span on the ambient tracer — the one call instrumented code
    makes.  Disabled path: contextvar read + attr check + shared no-op."""
    t = _ACTIVE_TRACER.get() or GLOBAL_TRACER
    if not t.enabled:
        return NOOP_SPAN
    return t.span(name)


# ---------------------------------------------------------------------------
# Chrome trace-event export (Perfetto / chrome://tracing)
# ---------------------------------------------------------------------------


def chrome_trace(root: Span, *, pid: int = 1, tid: int = 1) -> dict:
    """Render a finished trace as the Chrome trace-event JSON object format:
    complete ("ph": "X") events with microsecond timestamps relative to the
    root.  ``json.dump`` the result to a file and open it in Perfetto
    (ui.perfetto.dev) or chrome://tracing."""
    events = []
    base = root.t0
    for sp in root.walk():
        events.append({
            "name": sp.name,
            "ph": "X",
            "ts": (sp.t0 - base) * 1e6,
            "dur": sp.dur_s * 1e6,
            "pid": pid,
            "tid": tid,
            "args": {k: _jsonable(v) for k, v in sp.attrs.items()},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}
