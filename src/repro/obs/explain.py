"""EXPLAIN ANALYZE — the annotated operator tree behind a query.

:func:`explain_analyze` executes a :class:`~repro.core.plan.LogicalPlan`
under a (forced-on) tracer and reassembles the run's stats, the per-leaf
bound decisions, and the trace's per-phase spans into one JSON-friendly
operator tree plus a ``postgres``-style text rendering::

    TopK(k=25, asc, by=CP(mask, roi, (0.8, 1.0)) / AREA(roi))
      [candidates=600 decided_by_bounds=547 verified=53 bytes=868352 ...]
      -> Verify   [rounds=3 verified=53 bytes_loaded=868352 ...]
      -> CHIBounds [time_s=0.0021]
           CP(roi='provided', lv=0.8, uv=1.0): candidates=600 ...
      -> Source   [unit=mask candidates=600 mask_types=None]

The same structure is produced on every execution backend (host / device /
mesh) — candidates, decided-by-bounds, and verified counts are bit-identical
by the backend contract; only the timings differ.

``EXPLAIN <query>`` (without ANALYZE) goes through :func:`explain_plan`:
the logical operator tree only, nothing executed.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.exprs import (And, GroupEvalContext, Not, Or,
                          PairEvalContext, Pred)
from ..core.plan import LogicalPlan, compile_plan
from . import trace as trace_mod

__all__ = ["explain_plan", "explain_analyze", "render_text"]


def _pred_leaves(pred: Optional[Pred]) -> list:
    if pred is None:
        return []
    if isinstance(pred, (And, Or)):
        return _pred_leaves(pred.left) + _pred_leaves(pred.right)
    if isinstance(pred, Not):
        return _pred_leaves(pred.child)
    return [pred]


def _unit_of(ctx) -> str:
    if isinstance(ctx, PairEvalContext):
        return "image_pair"
    if isinstance(ctx, GroupEvalContext):
        return "image_group"
    return "mask"


def _root_op(plan: LogicalPlan) -> dict:
    kind = plan.kind
    if kind in ("topk", "filtered_topk"):
        return {"op": "TopK", "k": plan.k,
                "order": "desc" if plan.desc else "asc",
                "by": repr(plan.order_by)}
    if kind == "scalar_agg":
        return {"op": "Aggregate", "agg": plan.agg,
                "expr": repr(plan.agg_expr)}
    return {"op": "Filter", "predicate": repr(plan.predicate)}


def explain_plan(plan: LogicalPlan) -> dict:
    """``EXPLAIN`` (no ANALYZE): the logical operator tree, not executed."""
    plan.validate()
    root = _root_op(plan)
    children = []
    if plan.kind == "filtered_topk":
        children.append({"op": "Filter", "predicate": repr(plan.predicate)})
    children.append({"op": "CHIBounds",
                     "exprs": [{"expr": repr(e)} for e in plan.exprs()]})
    children.append({"op": "Source",
                     "mask_types": (None if plan.mask_types is None
                                    else list(plan.mask_types)),
                     "grouped": plan.grouped, "paired": plan.paired})
    root["children"] = children
    tree = {"kind": plan.kind, "analyzed": False, "tree": root}
    tree["text"] = render_text(root)
    return tree


def _bounds_rows(trace_root) -> list:
    """Per-expression bounds spans (classic ``bounds`` passes and pyramid
    ``bounds.tier`` rungs) pulled out of the trace.  Tier rungs carry the
    grid they ran at, so the rendered CHIBounds node shows the refinement
    ladder actually used and the index bytes each rung touched."""
    rows = []
    if trace_root is None:
        return rows
    for sp in trace_root.walk():
        if sp.name == "bounds":
            rows.append({"expr": sp.attrs.get("expr"),
                         "candidates": sp.attrs.get("candidates"),
                         "chi_bytes": sp.attrs.get("chi_bytes", 0),
                         "cached": bool(sp.attrs.get("cached", False)),
                         "time_s": sp.dur_s})
        elif sp.name == "bounds.tier":
            rows.append({"expr": sp.attrs.get("expr"),
                         "tier": sp.attrs.get("tier"),
                         "candidates": sp.attrs.get("candidates"),
                         "chi_bytes": sp.attrs.get("chi_bytes", 0),
                         "time_s": sp.dur_s})
    return rows


def _verify_rounds(trace_root) -> list:
    rounds = []
    if trace_root is None:
        return rounds
    for sp in trace_root.walk():
        if sp.name == "verify.round":
            rounds.append({"batch": sp.attrs.get("batch"),
                           "bytes_loaded": sp.attrs.get("bytes_loaded", 0),
                           "bytes_saved": sp.attrs.get("bytes_saved", 0),
                           "cache_hits": sp.attrs.get("cache_hits", 0),
                           "time_s": sp.dur_s})
    return rounds


def analyzed_tree(plan: LogicalPlan, run, trace_root=None) -> dict:
    """Annotate the operator tree with a finished run's per-operator stats.

    Works for any run produced by :func:`~repro.core.plan.compile_plan`
    (CP, pair, grouped, and filtered-top-k alike) on any backend."""
    s = run.stats
    root = _root_op(plan)
    root["stats"] = {
        "candidates": int(s.n_candidates),
        "decided_by_bounds": int(s.n_decided_by_bounds),
        "verified": int(s.n_verified),
        "rounds": int(s.n_rounds),
        "bytes_loaded": int(s.bytes_loaded),
        "bytes_saved": int(s.bytes_saved),
        "chi_bytes": int(s.chi_bytes),
        "bound_time_s": float(s.bound_time_s),
        "verify_time_s": float(s.verify_time_s),
        "load_fraction": float(s.load_fraction),
    }
    children = [{
        "op": "Verify",
        "stats": {"rounds": int(s.n_rounds), "verified": int(s.n_verified),
                  "bytes_loaded": int(s.bytes_loaded),
                  "bytes_saved": int(s.bytes_saved),
                  "time_s": float(s.verify_time_s)},
        "rounds": _verify_rounds(trace_root),
    }]
    if plan.predicate is not None:
        opt_report = getattr(run, "opt_report", None)
        if opt_report is not None:
            # the cost-based optimizer ran: report the conjunct order it
            # chose, each conjunct's estimated vs. actual rejection rate,
            # and the tier ladder it walked (re-deciding here would redo
            # un-memoized ladder passes and distort the stats)
            leaves = []
            for row in opt_report["conjuncts"]:
                entry = {"pred": row["pred"],
                         "start_tier": row["start_tier"]}
                if row.get("classic"):
                    entry["classic"] = True
                if row.get("est_reject") is not None:
                    entry["est_reject"] = round(float(row["est_reject"]), 4)
                if row.get("actual_reject") is not None:
                    entry["actual_reject"] = round(
                        float(row["actual_reject"]), 4)
                entry["evaluated"] = int(row.get("evaluated", 0))
                if row.get("tiers"):
                    entry["ladder"] = " -> ".join(
                        f"g{t['grid']}[{t['candidates']}cand "
                        f"{t['accepted']}acc {t['rejected']}rej]"
                        for t in row["tiers"])
                leaves.append(entry)
            children.append({"op": "Filter",
                             "predicate": repr(plan.predicate),
                             "order": list(opt_report["order"]),
                             "reordered": bool(opt_report["reordered"]),
                             "tier_grids": list(opt_report["tier_grids"]),
                             "leaves": leaves})
        else:
            # classic decide: leaf bounds are memoized on the run, so
            # re-deciding per leaf is free and exact
            leaves = []
            for leaf in _pred_leaves(plan.predicate):
                accept, reject = leaf.decide(run.expr_bounds, run.ctx)
                accept = np.asarray(accept, bool)
                reject = np.asarray(reject, bool)
                leaves.append({
                    "pred": repr(leaf),
                    "accepted_by_bounds": int(accept.sum()),
                    "rejected_by_bounds": int(reject.sum()),
                    "undecided": int((~(accept | reject)).sum()),
                })
            children.append({"op": "Filter",
                             "predicate": repr(plan.predicate),
                             "leaves": leaves})
    children.append({"op": "CHIBounds",
                     "stats": {"time_s": float(s.bound_time_s),
                               "chi_bytes": int(s.chi_bytes)},
                     "exprs": (_bounds_rows(trace_root) or
                               [{"expr": repr(e)} for e in plan.exprs()])})
    children.append({"op": "Source",
                     "unit": _unit_of(run.ctx),
                     "candidates": int(s.n_candidates),
                     "mask_types": (None if plan.mask_types is None
                                    else list(plan.mask_types)),
                     "dropped_masks": int(s.n_dropped_masks),
                     "packed": bool(getattr(run.ctx.store, "packed",
                                            False))})
    root["children"] = children
    return root


def _stats_line(d: dict) -> str:
    parts = []
    for k, v in d.items():
        if isinstance(v, float):
            parts.append(f"{k}={v:.4g}")
        else:
            parts.append(f"{k}={v}")
    return " ".join(parts)


def render_text(node: dict, indent: int = 0) -> str:
    """Indented text rendering of an (analyzed or plain) operator tree."""
    pad = "  " * indent
    head = node.get("op", "?")
    detail = {k: v for k, v in node.items()
              if k not in ("op", "children", "stats", "leaves", "exprs",
                           "rounds")}
    line = pad + ("-> " if indent else "") + head
    if detail:
        line += "(" + ", ".join(f"{k}={v}" for k, v in detail.items()) + ")"
    if node.get("stats"):
        line += f"  [{_stats_line(node['stats'])}]"
    out = [line]
    for leaf in node.get("leaves", ()):
        out.append(pad + "     " + _stats_line(leaf))
    for row in node.get("exprs", ()):
        out.append(pad + "     " + _stats_line(row))
    for child in node.get("children", ()):
        out.append(render_text(child, indent + 1))
    return "\n".join(out)


def explain_analyze(store, plan: LogicalPlan, *, provided_rois=None,
                    backend=None, verify_batch: Optional[int] = None,
                    bounds_hook=None, tracer: Optional[trace_mod.Tracer] = None,
                    label: str = "") -> dict:
    """Execute ``plan`` under a tracer and return the annotated report:

    ``{"query_id", "kind", "backend", "analyzed": True, "tree", "text",
    "stats", "trace", "chrome_trace", "n_results"/"value"}``

    Tracing is forced on for this query even when the ambient tracer is
    disabled (an explicitly requested EXPLAIN ANALYZE must not come back
    empty); pass ``tracer=`` to retain the trace in a specific ring buffer
    (the service passes its own, so ``GET /trace/<query_id>`` can replay
    it)."""
    plan.validate()
    t = tracer if tracer is not None else trace_mod.current_tracer()
    was_enabled = t.enabled
    t.enabled = True
    if verify_batch is None:
        ranked = plan.kind in ("topk", "filtered_topk") or (
            plan.kind == "scalar_agg" and plan.agg in ("MIN", "MAX"))
        verify_batch = 256 if ranked else max(len(store), 1)
    try:
        with t.activate():
            with t.query_span(label=label or plan.signature()) as root:
                root.set(kind=plan.kind, explain="analyze")
                run = compile_plan(store, plan,
                                   provided_rois=provided_rois,
                                   verify_batch=verify_batch,
                                   bounds_hook=bounds_hook,
                                   backend=backend)
                run.ensure(plan.k)
                result = run.result()
                if plan.kind in ("topk", "filtered_topk"):
                    root.set(n_results=len(result[0]))
                elif plan.kind == "filter":
                    root.set(n_results=len(result))
    finally:
        t.enabled = was_enabled

    tree = analyzed_tree(plan, run, root)
    report = {
        "query_id": root.attrs.get("query_id"),
        "kind": plan.kind,
        "analyzed": True,
        "backend": run.backend.name,
        "tree": tree,
        "text": render_text(tree),
        "stats": run.stats.as_dict(),
        "trace": root.to_dict(),
        "chrome_trace": trace_mod.chrome_trace(root),
    }
    if plan.kind == "scalar_agg":
        value = float(result)
        report["value"] = None if np.isnan(value) else value
    else:
        report["n_results"] = (len(result[0])
                               if plan.kind in ("topk", "filtered_topk")
                               else len(result))
    return report


def stats_fields(obj) -> list:
    """Names of the numeric fields of a stats dataclass (reflection used by
    the drift tests and the metrics adapters)."""
    return [f.name for f in dataclasses.fields(obj)
            if isinstance(getattr(obj, f.name), (int, float))
            and not isinstance(getattr(obj, f.name), bool)]
