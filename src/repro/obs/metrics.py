"""Unified pull-based metrics registry (DESIGN.md §10).

One registry absorbs every counter the system already keeps — the engine's
:class:`~repro.core.engine.ExecStats`, the store's ``IOStats``/``CacheStats``,
the scheduler's ``SchedulerStats``, the planner's ``CacheInfo`` — plus the
new first-class instruments: query/phase latency **histograms** (fixed
log-spaced buckets; p50/p95/p99 derivable at read time), per-kernel launch
counters + dispatch timing, and jit-recompile counters
(:mod:`repro.kernels.ops`).

Pull-based: live stats objects are wired in as *collectors* (callables
sampled at scrape time), so ``/metrics`` always reflects current state
without any push traffic on the hot path.  The exposition format is the
Prometheus text format (``GET /metrics`` serves it verbatim)::

    # HELP masksearch_query_phase_seconds ...
    # TYPE masksearch_query_phase_seconds histogram
    masksearch_query_phase_seconds_bucket{phase="verify",le="0.01"} 3
    ...

Naming convention: ``masksearch_<subsystem>_<quantity>[_<unit>]``, counters
end in ``_total``, durations in ``_seconds``, sizes in ``_bytes``.
"""

from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from typing import Callable, Optional, Sequence

from .. import lockcheck

__all__ = ["MetricsRegistry", "REGISTRY", "get_registry",
           "DEFAULT_TIME_BUCKETS", "dataclass_sampler"]

#: Log-spaced latency buckets, 100 µs … 10 s (upper bounds, seconds).
DEFAULT_TIME_BUCKETS = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


def _fmt(v) -> str:
    """Prometheus sample-value formatting (integers without the .0)."""
    f = float(v)
    if f == math.inf:
        return "+Inf"
    if f == -math.inf:
        return "-Inf"
    if f != f:
        return "NaN"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _escape(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace(
        "\n", "\\n")


def _label_str(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in labels.items())
    return "{" + inner + "}"


class _Child:
    """One labeled sample of a counter/gauge."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0.0
        self._lock = lockcheck.make_lock("metrics.child")

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def get(self) -> float:
        return self.value


class _HistChild:
    """One labeled fixed-bucket histogram."""

    __slots__ = ("buckets", "counts", "total", "count", "_lock")

    def __init__(self, buckets: Sequence[float]):
        self.buckets = tuple(buckets)
        self.counts = [0] * (len(self.buckets) + 1)   # +1 → +Inf
        self.total = 0.0
        self.count = 0
        self._lock = lockcheck.make_lock("metrics.hist")

    def observe(self, value: float) -> None:
        value = float(value)
        with self._lock:
            i = 0
            for i, ub in enumerate(self.buckets):        # noqa: B007
                if value <= ub:  # masklint: ignore[bounds-soundness] -- histogram bucket edge, not a CHI bound
                    break
            else:
                i = len(self.buckets)
            self.counts[i] += 1
            self.total += value
            self.count += 1

    def quantile(self, q: float) -> float:
        """Derive an approximate quantile (e.g. 0.5/0.95/0.99) from the
        bucket counts: linear interpolation inside the target bucket,
        clamped to the last finite edge for the +Inf bucket."""
        with self._lock:
            counts, total_n = list(self.counts), self.count
        if total_n == 0:
            return float("nan")
        rank = q * total_n
        cum = 0.0
        for i, c in enumerate(counts):
            prev_cum = cum
            cum += c
            if cum >= rank and c > 0:
                lo = self.buckets[i - 1] if i > 0 else 0.0
                hi = (self.buckets[i] if i < len(self.buckets)
                      else self.buckets[-1])
                frac = (rank - prev_cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        return self.buckets[-1]

    def summary(self) -> dict:
        with self._lock:
            count, total = self.count, self.total
        return {"count": count, "sum_s": total,
                "p50": self.quantile(0.5), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}


class _Family:
    """A named metric family; children are keyed by label values."""

    def __init__(self, name: str, mtype: str, help: str,
                 labelnames: Sequence[str] = (), buckets=None):
        self.name = name
        self.type = mtype
        self.help = help
        self.labelnames = tuple(labelnames)
        self.buckets = buckets
        self._children: "OrderedDict[tuple, object]" = OrderedDict()
        self._lock = lockcheck.make_lock("metrics.family")

    def labels(self, **labels):
        if tuple(sorted(labels)) != tuple(sorted(self.labelnames)):
            raise ValueError(
                f"metric {self.name} expects labels {self.labelnames}, "
                f"got {tuple(labels)}")
        key = tuple(str(labels[k]) for k in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = (_HistChild(self.buckets)
                         if self.type == "histogram" else _Child())
                self._children[key] = child
            return child

    # Unlabeled convenience surface.
    def inc(self, amount: float = 1.0) -> None:
        self.labels().inc(amount)

    def set(self, value: float) -> None:
        self.labels().set(value)

    def observe(self, value: float) -> None:
        self.labels().observe(value)

    def samples(self):
        """→ iterable of (label_dict, child)."""
        with self._lock:
            items = list(self._children.items())
        for key, child in items:
            yield dict(zip(self.labelnames, key)), child


class MetricsRegistry:
    """Owns metric families and scrape-time collectors; renders the
    Prometheus text exposition."""

    def __init__(self):
        self._families: "OrderedDict[str, _Family]" = OrderedDict()
        self._collectors: list = []
        self._lock = lockcheck.make_lock("metrics.registry")

    # -- family constructors (idempotent by name) -------------------------
    def _family(self, name, mtype, help, labelnames, buckets=None) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = _Family(name, mtype, help, labelnames, buckets)
                self._families[name] = fam
            elif fam.type != mtype:
                raise ValueError(f"metric {name} already registered as "
                                 f"{fam.type}, not {mtype}")
            return fam

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> _Family:
        return self._family(name, "counter", help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> _Family:
        return self._family(name, "gauge", help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS) -> _Family:
        return self._family(name, "histogram", help, labelnames,
                            buckets=tuple(buckets))

    def register_collector(self, fn: Callable[[], list]) -> None:
        """``fn() -> [(name, type, help, [(labels_dict, value), ...]), ...]``
        sampled at scrape time — the pull seam that absorbs live stats
        objects (ExecStats aggregates, CacheStats, SchedulerStats,
        CacheInfo) without copying them on the hot path."""
        with self._lock:
            self._collectors.append(fn)

    # -- scraping ---------------------------------------------------------
    def prometheus_text(self) -> str:
        lines: list = []
        with self._lock:
            families = list(self._families.values())
            collectors = list(self._collectors)
        for fam in families:
            samples = list(fam.samples())
            if not samples:
                continue
            lines.append(f"# HELP {fam.name} {_escape(fam.help)}")
            lines.append(f"# TYPE {fam.name} {fam.type}")
            for labels, child in samples:
                if fam.type == "histogram":
                    cum = 0
                    for i, ub in enumerate(child.buckets):
                        cum += child.counts[i]
                        bl = dict(labels)
                        bl["le"] = _fmt(ub)
                        lines.append(f"{fam.name}_bucket{_label_str(bl)} "
                                     f"{cum}")
                    bl = dict(labels)
                    bl["le"] = "+Inf"
                    lines.append(f"{fam.name}_bucket{_label_str(bl)} "
                                 f"{child.count}")
                    lines.append(f"{fam.name}_sum{_label_str(labels)} "
                                 f"{_fmt(child.total)}")
                    lines.append(f"{fam.name}_count{_label_str(labels)} "
                                 f"{child.count}")
                else:
                    lines.append(f"{fam.name}{_label_str(labels)} "
                                 f"{_fmt(child.get())}")
        for fn in collectors:
            for name, mtype, help, samples in fn():
                if not samples:
                    continue
                lines.append(f"# HELP {name} {_escape(help)}")
                lines.append(f"# TYPE {name} {mtype}")
                for labels, value in samples:
                    lines.append(f"{name}{_label_str(labels)} {_fmt(value)}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """JSON-friendly view of the first-class families (histograms as
        count/sum/p50/p95/p99 summaries) — what ``/stats`` embeds."""
        out: dict = {}
        with self._lock:
            families = list(self._families.values())
        for fam in families:
            fam_out: dict = {}
            for labels, child in fam.samples():
                key = ",".join(f"{k}={v}" for k, v in labels.items()) or "_"
                fam_out[key] = (child.summary()
                                if fam.type == "histogram" else child.get())
            if fam_out:
                out[fam.name] = fam_out
        return out


def dataclass_sampler(name_prefix: str, mtype: str, help: str,
                      getter: Callable[[], object],
                      labels: Optional[dict] = None) -> Callable[[], list]:
    """Build a collector that reflects every numeric field of a (live)
    stats dataclass into ``<name_prefix>_<field>`` samples — the adapter
    that puts ``IOStats``/``CacheStats``/``SchedulerStats``/``CacheInfo``
    behind the registry without hand-listing fields (a field added to the
    dataclass shows up at the next scrape automatically)."""
    labels = labels or {}

    def collect() -> list:
        obj = getter()
        out = []
        for f in dataclasses.fields(obj):
            v = getattr(obj, f.name)
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            out.append((f"{name_prefix}_{f.name}", mtype, help,
                        [(labels, float(v))]))
        return out

    return collect


REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-global registry (kernel launch/jit counters live here)."""
    return REGISTRY
