"""Observability for the MaskSearch repro (DESIGN.md §10).

Three pieces, one seam per concern:

* :mod:`.trace`   — contextvar-scoped span tracing (JSON + Chrome
  trace-event export; near-zero overhead when disabled).
* :mod:`.metrics` — the unified pull-based metrics registry (counters,
  gauges, fixed-bucket latency histograms; Prometheus text exposition).
* :mod:`.explain` — ``EXPLAIN [ANALYZE]``: the annotated operator tree.

``trace``/``metrics`` are dependency-free leaves (the engine, kernels, and
service all import them); ``explain`` sits *above* :mod:`repro.core` and is
loaded lazily so importing :mod:`repro.obs` from core never cycles.
"""

from . import metrics, trace  # noqa: F401
from .metrics import REGISTRY, MetricsRegistry, get_registry  # noqa: F401
from .trace import GLOBAL_TRACER, Span, Tracer, chrome_trace, span  # noqa: F401


def __getattr__(name):
    # importlib (not ``from . import``): the from-import form re-enters this
    # __getattr__ before the submodule is bound and recurses forever.
    if name in ("explain", "explain_plan", "explain_analyze", "render_text"):
        import importlib

        explain = importlib.import_module(".explain", __name__)
        return explain if name == "explain" else getattr(explain, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
